"""repro.memory: residual codecs, per-layer memory policy, the rewired
custom_vjp residual store, remat, byte accounting, and the zero-recompile
pin for codec selection under knob schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DitherCtx, DitherPolicy, PolicyProgram, Piecewise,
                        conv2d, dense, dithered_einsum, nsd,
                        quantize_cotangent)
from repro.obs import metrics as statslib
from repro.memory import (DEFAULT_NSD_S, MemoryPolicy, MemoryRule,
                          capacity_bytes, decode, dense_nbytes, encode,
                          footprint_totals, measured_bytes,
                          parse_memory_program, parse_mode, resid_key,
                          residual_report, stored_nbytes)


@pytest.fixture
def act(key):
    """A relu-activation-like residual (what the layers actually save)."""
    return jax.nn.relu(jax.random.normal(key, (16, 48), jnp.float32))


class TestCodecs:
    def test_fp32_is_identity(self, act, key):
        enc = encode("fp32", act, key)
        assert enc is act
        assert decode("fp32", enc) is act

    def test_bf16_round_trip(self, act, key):
        dec = decode("bf16", encode("bf16", act, key))
        assert dec.dtype == act.dtype and dec.shape == act.shape
        np.testing.assert_array_equal(
            np.asarray(dec), np.asarray(act.astype(jnp.bfloat16)
                                        .astype(jnp.float32)))

    @pytest.mark.parametrize("shape", [(16, 48), (4, 5, 7), (3, 8, 8, 6)])
    def test_nsd_bit_exact_vs_reference(self, key, shape):
        """The acceptance bar: encode->decode == nsd_quantize for the same
        key, with zero tolerance — incl. shapes that exercise padding."""
        x = jax.random.normal(key, shape, jnp.float32)
        k = resid_key(key)
        dec = decode("nsd", encode("nsd", x, k))
        ref = nsd.nsd_quantize(x, k, DEFAULT_NSD_S)
        assert dec.shape == x.shape and dec.dtype == x.dtype
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(ref))

    def test_nsd_scale_parameter(self, act, key):
        k = resid_key(key)
        dec = decode("nsd@0.5", encode("nsd@0.5", act, k))
        np.testing.assert_array_equal(
            np.asarray(dec), np.asarray(nsd.nsd_quantize(act, k, 0.5)))

    def test_int8_error_bounded_by_half_scale(self, key):
        x = jax.random.normal(key, (32, 64), jnp.float32) * 5.0
        enc = encode("int8", x, key)
        err = jnp.abs(decode("int8", enc) - x).reshape(-1, 64)
        assert float(jnp.max(err / (enc.scale / 2.0))) <= 1.001

    def test_int8_constant_row_exact(self, key):
        x = jnp.full((4, 16), 3.25, jnp.float32)
        dec = decode("int8", encode("int8", x, key))
        np.testing.assert_allclose(np.asarray(dec), np.asarray(x), rtol=0,
                                   atol=0)

    def test_int8_restores_shape_dtype(self, key):
        x = jax.random.normal(key, (2, 3, 4, 5), jnp.bfloat16)
        dec = decode("int8", encode("int8", x, key))
        assert dec.shape == x.shape and dec.dtype == x.dtype

    def test_stored_bytes_ordering(self):
        shape, dt = (64, 256), jnp.float32
        dense = dense_nbytes(shape, dt)
        assert stored_nbytes("fp32", shape, dt) == dense
        assert stored_nbytes("remat", shape, dt) == dense
        assert stored_nbytes("bf16", shape, dt) == dense // 2
        assert stored_nbytes("int8", shape, dt) < dense / 3.5
        assert stored_nbytes("nsd", shape, dt) < dense / 3.5

    def test_nsd_measured_at_most_capacity(self, act, key):
        enc = encode("nsd", act, resid_key(key))
        measured = int(measured_bytes("nsd", enc))
        assert capacity_bytes("nsd", enc) == stored_nbytes(
            "nsd", act.shape, act.dtype)
        assert measured <= capacity_bytes("nsd", enc)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="unknown residual mode"):
            parse_mode("fp64")
        with pytest.raises(ValueError, match="@-parameter"):
            parse_mode("int8@3")
        with pytest.raises(ValueError, match="s must be > 0"):
            parse_mode("nsd@0")
        assert parse_mode("nsd@0.5") == ("nsd", 0.5)


class TestMemoryPolicy:
    def test_last_match_wins_over_default(self):
        pol = MemoryPolicy(default="nsd",
                           rules=(MemoryRule("fc", "int8"),
                                  MemoryRule("fc1", "remat")))
        assert pol.mode_for("fc1") == "remat"
        assert pol.mode_for("fc0") == "int8"
        assert pol.mode_for("conv2") == "nsd"

    def test_glob_pattern(self):
        pol = MemoryPolicy(rules=(MemoryRule("L*.mlp.*", "nsd"),))
        assert pol.mode_for("L3.mlp.up") == "nsd"
        assert pol.mode_for("mlp.up") == "fp32"

    def test_parse_round_trip(self):
        pol = parse_memory_program("default=nsd@0.5;rule fc0:int8;"
                                   "rule c*:remat")
        assert pol.default == "nsd@0.5"
        assert pol.rules == (MemoryRule("fc0", "int8"),
                             MemoryRule("c*", "remat"))
        assert pol.mode_for("c3") == "remat"

    def test_parse_errors(self):
        with pytest.raises(ValueError, match="cannot parse clause"):
            parse_memory_program("bogus")
        with pytest.raises(ValueError, match="rule syntax"):
            parse_memory_program("rule fc0")
        with pytest.raises(ValueError, match="unknown residual mode"):
            parse_memory_program("default=fp64")
        with pytest.raises(ValueError, match=r"MemoryRule\('fc'\)"):
            parse_memory_program("rule fc:fp64")
        # registry-widened grammar: any registered quant codec is a mode
        pol = parse_memory_program("default=int4@g32;rule fc:m8")
        assert pol.default == "int4@g32"

    def test_policy_is_hashable(self):
        a = parse_memory_program("default=nsd;rule fc:int8")
        b = parse_memory_program("default=nsd;rule fc:int8")
        assert hash(a) == hash(b) and {a: 1}[b] == 1


def _grad_fn(x, pol, mem, name="fc"):
    def grads(w):
        ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 3, pol, memory=mem)
        return jax.grad(lambda xw: jnp.sum(
            dense(xw[0], xw[1], ctx=ctx, name=name) ** 2))((x, w))
    return grads


class TestResidualStore:
    """The rewired custom_vjp: fwd encodes, bwd decodes."""

    def test_fp32_mode_bit_identical_to_no_policy(self, key, act):
        w = jax.random.normal(key, (48, 8)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0)
        g_none = _grad_fn(act, pol, None)(w)
        g_fp32 = _grad_fn(act, pol, MemoryPolicy(default="fp32"))(w)
        for a, b in zip(g_none, g_fp32):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_remat_bit_identical_to_store(self, key, act):
        """Recompute-in-VJP must reproduce the stored-residual grads
        exactly (same keys -> same dither draws)."""
        w = jax.random.normal(key, (48, 8)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0)
        g_none = _grad_fn(act, pol, None)(w)
        g_rm = _grad_fn(act, pol, MemoryPolicy(default="remat"))(w)
        for a, b in zip(g_none, g_rm):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("variant", ["paper", "int8"])
    def test_nsd_residual_touches_only_dw(self, key, act, variant):
        """dx = g~ . W^T never reads x: it is bit-identical across residual
        modes; dW sees exactly the decoded (quantized) activations."""
        w = jax.random.normal(key, (48, 8)) * 0.1
        pol = DitherPolicy(variant=variant, s=2.0)
        dx0, _ = _grad_fn(act, pol, None)(w)
        dxn, _ = _grad_fn(act, pol, MemoryPolicy(default="nsd"))(w)
        np.testing.assert_array_equal(np.asarray(dx0), np.asarray(dxn))

    def test_nsd_residual_dw_matches_manual_product(self, key, act):
        """dW under the nsd codec == decode(encode(x))^T @ g~ computed by
        hand from the same keys — pins both the codec wiring and the RNG
        stream separation (RESID_SALT)."""
        w = jax.random.normal(key, (48, 8)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0)
        _, dw = _grad_fn(act, pol, MemoryPolicy(default="nsd"))(w)

        ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 3, pol)
        layer_key = ctx.key_for("fc")
        y = act @ w
        g = 2.0 * y  # cotangent of sum(y**2)
        gq = quantize_cotangent(g, layer_key, pol.knobs(), pol.spec(), "fc")
        x_hat = nsd.nsd_quantize(act, resid_key(layer_key), DEFAULT_NSD_S)
        np.testing.assert_allclose(np.asarray(dw),
                                   np.asarray(x_hat.T @ gq), rtol=1e-6)

    def test_conv_and_einsum_modes(self, key):
        x = jax.random.normal(key, (2, 8, 8, 3))
        w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 3, 4)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0)

        def grads(mem):
            ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 1, pol,
                                     memory=mem)
            return jax.grad(lambda xw: jnp.sum(conv2d(
                xw[0], xw[1], ctx=ctx, name="c1") ** 2))((x, w))

        dx0, dw0 = grads(None)
        for mode in ("nsd", "int8", "bf16", "remat"):
            dxm, dwm = grads(MemoryPolicy(default=mode))
            # conv dx pulls back through w only: exact in every mode
            np.testing.assert_array_equal(np.asarray(dx0), np.asarray(dxm))
            assert np.all(np.isfinite(np.asarray(dwm)))
        xe = jax.random.normal(key, (4, 6, 8))
        we = jax.random.normal(jax.random.fold_in(key, 2), (8, 5)) * 0.1
        ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 1, pol,
                                 memory=MemoryPolicy(default="nsd"))
        g = jax.grad(lambda w: jnp.sum(dithered_einsum(
            "bte,eh->bth", xe, w, ctx=ctx, name="ein") ** 2))(we)
        assert np.all(np.isfinite(np.asarray(g)))

    def test_per_layer_rules_resolve_statically(self, key):
        pol = DitherPolicy(variant="paper", s=2.0)
        mem = parse_memory_program("default=nsd;rule fc2:fp32")
        ctx = DitherCtx.for_step(key, 0, pol, memory=mem)
        assert ctx.resolve("fc1").spec.residual == "nsd"
        assert ctx.resolve("fc2").spec.residual == "fp32"
        # and through a program path
        prog = PolicyProgram(base=pol)
        ctx2 = DitherCtx.for_step(key, 0, pol, program=prog, memory=mem)
        assert ctx2.resolve("fc1").spec.residual == "nsd"

    def test_remat_strips_telemetry(self, key):
        """io effects can't cross jax.checkpoint: remat resolution keeps
        collect_stats on the spec, the op wrapper strips it (pinned here
        via the emitted rows: memory row yes, sparsity row no)."""
        statslib.reset()
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag="rm/")
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.1
        ctx = DitherCtx.for_step(key, 0, pol,
                                 memory=MemoryPolicy(default="remat"))
        jax.grad(lambda w: jnp.sum(dense(x, w, ctx=ctx, name="fc") ** 2))(w)
        rows = statslib.memory_rows("rm/fc")
        assert rows.shape == (1, 3)
        assert rows[0, 0] == rows[0, 1] == rows[0, 2]  # raw-input store
        assert statslib.row_count("rm/fc") == 0  # no sparsity telemetry

    @pytest.mark.parametrize("mode", ["nsd", "remat"])
    def test_no_memory_rows_without_differentiation(self, key, mode):
        """Telemetry fires only when a backward will consume the residual:
        a plain (un-differentiated) forward with a collect_stats ctx emits
        nothing, for codec AND remat layers alike."""
        statslib.reset()
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag="nd/")
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.1
        ctx = DitherCtx.for_step(key, 0, pol,
                                 memory=MemoryPolicy(default=mode))
        dense(x, w, ctx=ctx, name="fc").block_until_ready()
        assert statslib.memory_tags() == []


class TestCompileCounter:
    def test_codec_adds_zero_recompiles_under_s_ramp(self, key):
        """The acceptance pin: codec selection is static per layer, so a
        scheduled s ramp still compiles exactly once."""
        x = jax.random.normal(key, (8, 16))
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", collect_stats=True,
                              stats_tag="mc/"),
            s=Piecewise(((0, 1.0), (2, 2.0), (4, 4.0))))
        mem = parse_memory_program("default=nsd;rule fc2:int8")
        traces = []

        @jax.jit
        def step(w, i, k):
            traces.append(1)
            ctx = DitherCtx.for_step(k, i, prog.base, program=prog,
                                     memory=mem)

            def loss(w):
                h = dense(x, w["w1"], ctx=ctx, name="fc1")
                return jnp.sum(dense(h, w["w2"], ctx=ctx, name="fc2") ** 2)

            g = jax.grad(loss)(w)
            return jax.tree.map(lambda a, b: a - 0.01 * b, w, g)

        statslib.reset()
        w = {"w1": jax.random.normal(key, (16, 24)) * 0.1,
             "w2": jax.random.normal(jax.random.fold_in(key, 1),
                                     (24, 8)) * 0.1}
        for i in range(6):
            w = step(w, jnp.int32(i), key)
        assert len(traces) == 1, f"codec + s ramp retraced {len(traces)}x"
        # the ramp took effect under the codec path
        jax.effects_barrier()
        deltas = statslib.rows("mc/fc1")[:, 2]
        assert len(np.unique(np.round(deltas / deltas[0], 3))) >= 3

    def test_memory_policy_change_retraces(self, key):
        """Flipping the (static) codec IS a retrace — exactly once."""
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0)
        traces = []

        def step(w, mem):
            traces.append(1)
            ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 0, pol,
                                     memory=mem)
            return jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)

        jit_step = jax.jit(step, static_argnames=("mem",))
        for mem in (MemoryPolicy(default="fp32"),
                    MemoryPolicy(default="nsd"),
                    MemoryPolicy(default="nsd")):
            jit_step(w, mem)
        assert len(traces) == 2


class TestAccounting:
    def _loss(self, p, b, ctx):
        h = dense(b, p["w1"], ctx=ctx, name="fc1")
        return jnp.sum(dense(h, p["w2"], ctx=ctx, name="fc2") ** 2)

    def test_report_and_totals(self):
        params = {"w1": jnp.zeros((64, 32)), "w2": jnp.zeros((32, 8))}
        batch = jnp.zeros((16, 64))
        mem = parse_memory_program("default=nsd;rule fc2:remat")
        rep = residual_report(self._loss, params, batch, memory=mem)
        assert set(rep) == {"fc1", "fc2"}
        assert rep["fc1"] == (stored_nbytes("nsd", (16, 64), jnp.float32),
                              16 * 64 * 4)
        assert rep["fc2"] == (16 * 32 * 4, 16 * 32 * 4)  # remat: dense
        stored, dense_b = footprint_totals(rep)
        assert stored < dense_b

    def test_no_memory_policy_reports_dense(self):
        params = {"w1": jnp.zeros((64, 32)), "w2": jnp.zeros((32, 8))}
        rep = residual_report(self._loss, params, jnp.zeros((4, 64)))
        stored, dense_b = footprint_totals(rep)
        assert stored == dense_b > 0

    def test_off_policy_reports_nothing(self):
        params = {"w1": jnp.zeros((64, 32)), "w2": jnp.zeros((32, 8))}
        rep = residual_report(self._loss, params, jnp.zeros((4, 64)),
                              policy=DitherPolicy(variant="off"))
        assert rep == {}

    def test_price_memory(self):
        from repro.launch.costmodel import price_memory
        out = price_memory(1e9, 4e9, n_chips=4, batch=8,
                           fixed_bytes_per_chip=8e9, hbm_bytes=16e9)
        assert out["residual_compression"] == pytest.approx(4.0)
        # dense: 1e9/chip residual, 8e9 headroom -> batch 8 * 8 = 64
        assert out["est_max_batch_dense"] == pytest.approx(64.0)
        assert out["est_max_batch_stored"] == pytest.approx(256.0)


class TestTelemetryAndHarness:
    def test_memory_rows_and_compression(self, key):
        statslib.reset()
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag="mt/")
        x = jax.nn.relu(jax.random.normal(key, (16, 64)))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 8)) * 0.1
        ctx = DitherCtx.for_step(key, 0, pol,
                                 memory=MemoryPolicy(default="nsd"))
        for _ in range(2):
            jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)
        rows = statslib.memory_rows("mt/fc")
        assert rows.shape == (2, 3)
        # measured occupancy <= HBM capacity <= dense, rowwise
        assert np.all(rows[:, 0] <= rows[:, 1]) and np.all(
            rows[:, 1] <= rows[:, 2])
        assert statslib.overall_residual_compression("mt/") > 3.5
        assert statslib.overall_residual_compression(
            "mt/", capacity=True) > 3.0
        summ = statslib.memory_summary()["mt/fc"]
        assert summ["occupancy_compression"] > 3.5
        assert summ["capacity_compression"] > 3.0
        assert summ["n_records"] == 2

    def test_train_classifier_with_memory(self):
        from repro.configs import paper_models as pm
        from benchmarks.harness import train_classifier
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag="th/")
        out = train_classifier(pm.lenet300100(), pol, steps=3,
                               memory="default=nsd")
        assert np.isfinite(out["acc"])
        assert out["residual_compression"] > 3.5


class TestStaticSpecResidual:
    def test_default_is_fp32(self):
        assert DitherPolicy().spec().residual == "fp32"

    def test_with_key_preserves_memory(self, key):
        mem = MemoryPolicy(default="nsd")
        ctx = DitherCtx.for_step(key, 0, DitherPolicy(), memory=mem)
        clone = ctx.with_key(jax.random.fold_in(key, 9))
        assert clone.memory is mem
