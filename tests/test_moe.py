"""MoE dispatch correctness: the einsum path vs a per-token python oracle,
capacity dropping, aux loss, and the a2a path vs einsum (in a subprocess
with 8 fake devices, since EP needs a >1 model axis)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, init_moe, moe_einsum


def _oracle(params, x2d, cfg):
    """Per-token loop: route, run chosen experts, weight-combine."""
    logits = x2d @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    outs = []
    for t in range(x2d.shape[0]):
        acc = jnp.zeros((x2d.shape[1],), jnp.float32)
        for j in range(cfg.top_k):
            e = int(top_i[t, j])
            g = x2d[t] @ params["w_gate"][e]
            u = x2d[t] @ params["w_up"][e]
            h = jax.nn.silu(g) * u
            acc = acc + float(top_p[t, j]) * (h @ params["w_down"][e])
        outs.append(acc)
    return jnp.stack(outs)


def test_einsum_dispatch_matches_oracle(key):
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=4.0, dispatch="einsum")
    params, _ = init_moe(key, 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (12, 8))
    out, aux = moe_einsum(params, x, cfg, None)
    ref = _oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)
    assert np.isfinite(float(aux))


def test_capacity_drops_tokens(key):
    """capacity_factor << 1 must drop tokens (outputs become zero), not
    crash or corrupt other tokens."""
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8,
                    capacity_factor=0.26, dispatch="einsum")
    params, _ = init_moe(key, 4, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))
    out, _ = moe_einsum(params, x, cfg, None)
    norms = jnp.linalg.norm(out, axis=-1)
    assert int(jnp.sum(norms == 0)) >= 8  # over-capacity tokens zeroed
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_grads_flow(key):
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, dispatch="einsum")
    params, _ = init_moe(key, 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (12, 8))

    def loss(p):
        out, aux = moe_einsum(p, x, cfg, None)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.linalg.norm(g[name])) > 0, name


A2A_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.models.moe import MoEConfig, init_moe, moe_einsum, moe_a2a
    from repro.parallel import axes as axlib

    from repro.launch import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                    capacity_factor=8.0)  # high cf: no drops -> exact match
    params, _ = init_moe(key, 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    rules = axlib.tp_dp_rules(mesh)
    with axlib.use_rules(rules):
        out_ref, aux_ref = moe_einsum(params, x, cfg, None)
        out_a2a, aux_a2a = jax.jit(
            lambda p, x: moe_a2a(p, x, cfg, None))(params, x)
    err = float(jnp.linalg.norm(out_a2a - out_ref) /
                (jnp.linalg.norm(out_ref) + 1e-9))
    assert err < 2e-4, err
    print("A2A_OK", err)
""")


def test_a2a_matches_einsum_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", A2A_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "A2A_OK" in out.stdout, out.stdout + out.stderr
