"""Serving-path consistency: prefill + step-by-step decode must agree with
the full training forward, including sliding-window ring buffers and
hybrid/meta-token paths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import hybrid as H
from repro.models import transformer as tf
from repro.models.layers import ring_slot_positions, ring_write_slot


class TestRingBuffer:
    def test_full_buffer_positions(self):
        t = jnp.asarray(5)
        pos, valid = ring_slot_positions(t, 8, 0)
        np.testing.assert_array_equal(np.asarray(pos)[:6], np.arange(6))
        np.testing.assert_array_equal(np.asarray(valid),
                                      [1, 1, 1, 1, 1, 1, 0, 0])

    def test_ring_wraps(self):
        s_buf = 4
        seen = {}
        for t in range(10):
            slot = int(ring_write_slot(jnp.asarray(t), s_buf, 0))
            seen[slot] = t
            pos, valid = ring_slot_positions(jnp.asarray(t), s_buf, 0)
            pos, valid = np.asarray(pos), np.asarray(valid)
            for s in range(s_buf):
                if valid[s]:
                    assert pos[s] == seen[s], (t, s, pos, seen)

    def test_prefix_slots_pinned(self):
        s_buf, prefix = 6, 2
        for t in range(2, 12):
            slot = int(ring_write_slot(jnp.asarray(t), s_buf, prefix))
            assert slot >= prefix
            pos, valid = ring_slot_positions(jnp.asarray(t), s_buf, prefix)
            pos, valid = np.asarray(pos), np.asarray(valid)
            assert pos[0] == 0 and pos[1] == 1
            assert valid[0] and valid[1]
            ring_pos = pos[prefix:][valid[prefix:]]
            assert len(set(ring_pos.tolist())) == len(ring_pos)
            assert all(p >= prefix for p in ring_pos)


def _decode_all(model_cfg, params, toks, max_len, decode_fn):
    cache_init, decode_step = decode_fn
    cache = cache_init(model_cfg, toks.shape[0], max_len)
    outs = []
    for t in range(toks.shape[1]):
        lg, cache = decode_step(params, model_cfg, cache, toks[:, t:t + 1],
                                jnp.asarray(t, jnp.int32))
        outs.append(lg)
    return jnp.concatenate(outs, axis=1)


class TestLMDecode:
    def test_dense_lm_decode_matches_forward(self, key):
        cfg = tf.LMConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                          n_kv_heads=2, d_ff=64, vocab=64,
                          dtype=jnp.float32, remat=False)
        params, _ = tf.init_lm(key, cfg)
        toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 10), 0, 64)
        logits_train, _ = tf.forward(params, cfg, toks)
        logits_dec = _decode_all(cfg, params, toks, 10,
                                 (tf.init_cache, tf.decode_step))
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_train),
                                   rtol=2e-3, atol=2e-3)

    def test_windowed_lm_decode_matches_forward(self, key):
        """Ring-buffer decode == train forward with the same window mask."""
        cfg = tf.LMConfig(name="t", n_layers=3, d_model=32, n_heads=4,
                          n_kv_heads=4, d_ff=64, vocab=64, window=4,
                          window_pattern=2, dtype=jnp.float32, remat=False)
        params, _ = tf.init_lm(key, cfg)
        toks = jax.random.randint(jax.random.fold_in(key, 1), (1, 12), 0, 64)
        logits_train, _ = tf.forward(params, cfg, toks)
        logits_dec = _decode_all(cfg, params, toks, 12,
                                 (tf.init_cache, tf.decode_step))
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_train),
                                   rtol=3e-3, atol=3e-3)

    def test_prefill_then_decode(self, key):
        cfg = tf.LMConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                          n_kv_heads=2, d_ff=64, vocab=64,
                          dtype=jnp.float32, remat=False)
        params, _ = tf.init_lm(key, cfg)
        toks = jax.random.randint(jax.random.fold_in(key, 1), (1, 9), 0, 64)
        # prefill on the first 8, then decode token 9 and compare to the
        # all-at-once forward
        logits_pre, cache, t = tf.prefill(params, cfg, toks[:, :8], 16)
        lg_step, _ = tf.decode_step(params, cfg, cache, toks[:, 8:9], t + 1)
        logits_full, _ = tf.forward(params, cfg, toks)
        np.testing.assert_allclose(np.asarray(lg_step[:, 0]),
                                   np.asarray(logits_full[:, -1]),
                                   rtol=3e-3, atol=3e-3)


class TestHybridDecode:
    def test_hybrid_decode_matches_forward(self, key):
        cfg = H.HybridConfig(name="t", n_layers=4, d_model=32, n_heads=4,
                             n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
                             d_state=4, window=4, n_meta_tokens=2,
                             dtype=jnp.float32, remat=False)
        params, _ = H.init_hybrid_lm(key, cfg)
        toks = jax.random.randint(jax.random.fold_in(key, 1), (1, 6), 0, 64)
        logits_train, _ = H.forward(params, cfg, toks)

        # decode: first replay the meta tokens into the attention caches
        cache = H.init_cache(cfg, 1, 6 + cfg.n_meta_tokens)
        meta = params["head"]["meta_tokens"]
        x_meta = jnp.broadcast_to(meta[None], (1,) + meta.shape)
        # replay meta positions through the layer stack manually
        from repro.models import layers as L
        from repro.models import mamba as M
        for t in range(cfg.n_meta_tokens):
            x = x_meta[:, t:t + 1].astype(cfg.dtype)
            for i in range(cfg.n_layers):
                p = L.layer_slice(params["layers"], i)
                h = L.rms_norm(x, p["ln1"])
                local = cfg.layer_is_local(i)
                acfg = cfg.attn_cfg(cfg.window if local else None,
                                    cfg.n_meta_tokens if local else 0)
                attn_y, kv = L.attention(
                    p["attn"], h, jnp.zeros((1,), jnp.int32) + t, acfg,
                    kv_cache=cache[i]["kv"], cache_index=jnp.asarray(t))
                ssm_y, st = M.mamba_decode_step(p["mixer"], h,
                                                cache[i]["ssm"], cfg.ssm)
                mixed = 0.5 * (L.rms_norm(attn_y, p["norm_attn"]) +
                               L.rms_norm(ssm_y, p["norm_ssm"]))
                x = x + mixed
                h2 = L.rms_norm(x, p["ln2"])
                x = x + L.mlp(p["mlp"], h2,
                              L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act))
                cache[i] = {"kv": kv, "ssm": st}
        outs = []
        for t in range(6):
            lg, cache = H.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                      jnp.asarray(cfg.n_meta_tokens + t))
            outs.append(lg)
        logits_dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_train),
                                   rtol=5e-3, atol=5e-3)
