"""Extra attention-correctness coverage: sliding-window pattern selection,
RoPE properties, and the Pallas kernel-variant training path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DitherCtx, DitherPolicy, dense
from repro.models import layers as L
from repro.models import transformer as tf


class TestWindowPattern:
    def test_gemma3_5to1_pattern(self):
        cfg = tf.LMConfig(name="t", n_layers=12, d_model=32, n_heads=2,
                          n_kv_heads=1, d_ff=64, vocab=32, window=8,
                          window_pattern=5)
        locals_ = [cfg.layer_is_local(i) for i in range(12)]
        # layers 5 and 11 (1-indexed 6th/12th) are global
        assert locals_ == [True] * 5 + [False] + [True] * 5 + [False]

    def test_window_mask_blocks_far_tokens(self):
        acfg = L.AttnConfig(d_model=8, n_heads=1, n_kv_heads=1, head_dim=8,
                            window=4)
        pos = jnp.arange(10)[None, :]
        m = np.asarray(L.attention_mask(pos, pos, acfg))[0]
        assert m[9, 9] and m[9, 6]  # within window of 4
        assert not m[9, 5] and not m[9, 0]  # outside window
        assert not m[0, 1]  # causal

    def test_global_layer_attends_everywhere_causal(self):
        acfg = L.AttnConfig(d_model=8, n_heads=1, n_kv_heads=1, head_dim=8,
                            window=None)
        pos = jnp.arange(10)[None, :]
        m = np.asarray(L.attention_mask(pos, pos, acfg))[0]
        assert m[9, 0] and m[9, 9] and not m[0, 9]

    def test_windowed_vs_global_forward_differs(self, key):
        """The traced is_local flag must actually switch the mask."""
        base = dict(name="t", n_layers=2, d_model=32, n_heads=2,
                    n_kv_heads=1, d_ff=64, vocab=64, dtype=jnp.float32,
                    remat=False)
        cfg_win = tf.LMConfig(**base, window=2, window_pattern=0)
        cfg_full = tf.LMConfig(**base)
        params, _ = tf.init_lm(key, cfg_win)
        toks = jax.random.randint(jax.random.fold_in(key, 1), (1, 12), 0, 64)
        lg_win, _ = tf.forward(params, cfg_win, toks)
        lg_full, _ = tf.forward(params, cfg_full, toks)
        # same params, different masks -> different logits at late positions
        assert not np.allclose(np.asarray(lg_win[:, -1]),
                               np.asarray(lg_full[:, -1]), atol=1e-4)


class TestRope:
    def test_relative_position_property(self, key):
        """<rope(q,i), rope(k,j)> depends only on i-j (the RoPE invariant)."""
        q = jax.random.normal(key, (1, 1, 1, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))

        def score(qi, kj):
            qr = L.apply_rope(q, jnp.asarray([[qi]]))
            kr = L.apply_rope(k, jnp.asarray([[kj]]))
            return float(jnp.sum(qr * kr))

        np.testing.assert_allclose(score(5, 3), score(10, 8), rtol=1e-4)
        np.testing.assert_allclose(score(7, 0), score(107, 100), rtol=1e-3)
        assert abs(score(5, 3) - score(5, 0)) > 1e-5

    def test_rope_norm_preserving(self, key):
        x = jax.random.normal(key, (2, 4, 3, 16))
        y = L.apply_rope(x, jnp.arange(4)[None, :])
        np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                                   np.asarray(jnp.linalg.norm(x, axis=-1)),
                                   rtol=1e-5)

    def test_theta_zero_disables(self, key):
        x = jax.random.normal(key, (1, 4, 2, 8))
        y = L.apply_rope(x, jnp.arange(4)[None, :], theta=0.0)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestKernelVariant:
    def test_kernel_variant_trains(self, key):
        """VARIANT_KERNEL: the Pallas fused-NSD + tile-skip backward inside
        a real training loop (128-aligned layer)."""
        x = jax.random.normal(key, (128, 128))
        w_true = jax.random.normal(jax.random.fold_in(key, 1), (128, 128))
        y = x @ w_true * 0.01
        w = jnp.zeros((128, 128))
        pol = DitherPolicy(variant="kernel", s=2.0)

        @jax.jit
        def step(w, i):
            ctx = DitherCtx.for_step(key, i, pol)
            loss, g = jax.value_and_grad(
                lambda w: jnp.mean((dense(x, w, ctx=ctx, name="fc") - y) ** 2)
            )(w)
            return w - 0.5 * g, loss

        losses = []
        for i in range(30):
            w, loss = step(w, i)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, losses

    def test_kernel_matches_paper_variant_closely(self, key):
        x = jax.random.normal(key, (128, 128))
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 128)) * 0.05

        def grad(variant):
            ctx = DitherCtx.for_step(key, 0, DitherPolicy(variant=variant,
                                                          s=2.0))
            return jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)

        g_k, g_p = grad("kernel"), grad("paper")
        rel = float(jnp.linalg.norm(g_k - g_p) / jnp.linalg.norm(g_p))
        assert rel < 0.03, rel  # only the absmax-int8 x/w operand error
