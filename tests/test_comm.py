"""repro.comm: wire format round trips, pack kernels, compressed ring
all-reduce error bounds, CommPolicy routing, error-feedback conservation."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import stat_utils

from repro.comm import (CommPolicy, RingConfig, compress_tree,
                        init_comm_state, pack_nsd, ring_allreduce_nsd,
                        topk_error_feedback, unpack_nsd, wireformat)
from repro.core import nsd
from repro.obs import metrics as statslib
from repro.kernels.pack.pack import (bitmap_pack_blocked,
                                     bitmap_unpack_blocked)
from repro.kernels.pack.ref import (bitmap_pack_blocked_ref,
                                    bitmap_unpack_blocked_ref)


class TestWireFormat:
    @pytest.mark.parametrize("shape", [(1024,), (300, 17), (8, 9, 10)])
    @pytest.mark.parametrize("s", [1.0, 4.0])
    def test_roundtrip_bit_exact_vs_core(self, key, shape, s):
        """unpack(pack(x)) == nsd_quantize_int8(x).dequantize() bit-exactly
        for the same PRNG key (the acceptance criterion)."""
        x = jax.random.normal(key, shape, jnp.float32) * 0.1
        p = pack_nsd(x, key, s)
        want = nsd.nsd_quantize_int8(x, key, s).dequantize()
        np.testing.assert_array_equal(np.asarray(unpack_nsd(p)),
                                      np.asarray(want))

    def test_roundtrip_under_jit(self, key):
        x = jax.random.normal(key, (513,), jnp.float32)
        f = jax.jit(lambda x, k: unpack_nsd(pack_nsd(x, k, 2.0)))
        want = nsd.nsd_quantize_int8(x, key, 2.0).dequantize()
        np.testing.assert_array_equal(np.asarray(f(x, key)),
                                      np.asarray(want))

    def test_bf16_dtype_preserved(self, key):
        x = jax.random.normal(key, (512,), jnp.bfloat16)
        out = unpack_nsd(pack_nsd(x, key, 2.0))
        assert out.dtype == jnp.bfloat16

    def test_bitmap_helpers_inverse(self, key):
        bits = jax.random.bernoulli(key, 0.1, (16, 256))
        packed = wireformat.pack_bitmap(bits)
        assert packed.dtype == jnp.uint8 and packed.shape == (16, 32)
        np.testing.assert_array_equal(
            np.asarray(wireformat.unpack_bitmap(packed)), np.asarray(bits))

    def test_wire_bytes_at_paper_sparsity_point(self, key):
        """At ~92% sparsity the packed tensor must be <= 25% of dense f32
        (acceptance criterion; in practice it is ~5%)."""
        x = jax.random.normal(key, (512, 512), jnp.float32)
        # dither key must be independent of the data key (else noise
        # correlates with the signal and sparsity drops — see test_kernels)
        qkey = jax.random.fold_in(key, 1234)
        s = 8.0  # ~90-92% sparsity on a gaussian (paper fig. 2)
        sparsity = float(jnp.mean(nsd.nsd_quantize(x, qkey, s) == 0))
        assert sparsity > 0.88, sparsity
        p = pack_nsd(x, qkey, s)
        ratio = int(p.wire_bytes()) / p.dense_bytes()
        assert ratio <= 0.25, (sparsity, ratio)

    def test_wire_bytes_honest_worst_case(self, key):
        """A dense (never-zero) tensor must cost MORE than 1 byte/elem —
        the format cannot under-report."""
        x = jax.random.normal(key, (2048,), jnp.float32) * 100.0
        p = pack_nsd(x, key, 0.01)  # tiny s -> almost nothing becomes zero
        assert int(p.nnz) > 1900
        assert int(p.wire_bytes()) > int(p.nnz)  # levels + bitmap + deltas

    def test_zero_tensor(self, key):
        p = pack_nsd(jnp.zeros((640,)), key, 2.0)
        assert int(p.nnz) == 0
        np.testing.assert_array_equal(np.asarray(unpack_nsd(p)),
                                      np.zeros(640, np.float32))

    def test_outlier_hits_int8_clip_guard(self, key):
        """A single huge spike saturates INT8_CLIP (k would be ~181
        unclipped: Delta = s*std ~ s*|spike|/sqrt(n), so k ~ sqrt(n)/s)
        and the round trip must STILL be bit-exact vs repro.core.nsd —
        both sides clip identically."""
        x = (jax.random.normal(key, (8192,), jnp.float32) * 1e-3)
        x = x.at[0].set(1e6)
        p = pack_nsd(x, key, 0.5)
        assert int(jnp.max(jnp.abs(p.levels))) == nsd.INT8_CLIP
        want = nsd.nsd_quantize_int8(x, key, 0.5).dequantize()
        np.testing.assert_array_equal(np.asarray(unpack_nsd(p)),
                                      np.asarray(want))


# Compiled-mode guard: interpret=True must pass everywhere. The kernels
# now use the Mosaic-lowerable sublane-rotate + OR-reduce layout (no
# lane-dim reshape — tests/test_pack_layout.py pins that structurally),
# but CPU still has no compiled pallas_call at all, so the compiled
# variant stays xfail(strict=False): a visible xfail on CPU CI and a
# plain pass on a real TPU host.
INTERPRET_MODES = [
    True,
    pytest.param(False, marks=pytest.mark.xfail(
        strict=False,
        reason="CPU has no compiled pallas; on TPU the sublane-rotate "
               "layout is expected to compile and pass")),
]


class TestPackKernels:
    @pytest.mark.parametrize("interpret", INTERPRET_MODES)
    @pytest.mark.parametrize("shape", [(128, 128), (256, 512), (384, 128)])
    def test_pack_kernel_vs_ref(self, key, shape, interpret):
        x = jax.random.normal(key, shape, jnp.float32)
        k8 = nsd.nsd_quantize_int8(x, key, 4.0).k
        bm_k, nnz_k = bitmap_pack_blocked(k8, interpret=interpret)
        bm_r, nnz_r = bitmap_pack_blocked_ref(k8)
        np.testing.assert_array_equal(np.asarray(bm_k), np.asarray(bm_r))
        np.testing.assert_array_equal(np.asarray(nnz_k), np.asarray(nnz_r))

    @pytest.mark.parametrize("interpret", INTERPRET_MODES)
    def test_unpack_kernel_vs_ref(self, key, interpret):
        x = jax.random.normal(key, (256, 256), jnp.float32)
        k8 = nsd.nsd_quantize_int8(x, key, 4.0).k
        bm, _ = bitmap_pack_blocked(k8, interpret=interpret)
        np.testing.assert_array_equal(
            np.asarray(bitmap_unpack_blocked(bm, interpret=interpret)),
            np.asarray(bitmap_unpack_blocked_ref(bm)))

    @pytest.mark.parametrize("interpret", INTERPRET_MODES)
    def test_kernel_roundtrip_recovers_occupancy(self, key, interpret):
        x = jax.random.normal(key, (128, 256), jnp.float32)
        k8 = nsd.nsd_quantize_int8(x, key, 2.0).k
        bm, _ = bitmap_pack_blocked(k8, interpret=interpret)
        mask = bitmap_unpack_blocked(bm, interpret=interpret)
        np.testing.assert_array_equal(
            np.asarray(mask), np.asarray((k8 != 0).astype(jnp.int8)))

    @pytest.mark.parametrize("interpret", INTERPRET_MODES)
    def test_kernel_matches_wireformat_bitmap(self, key, interpret):
        """Kernel and jnp wire-format reference share the bit convention."""
        x = jax.random.normal(key, (128, 128), jnp.float32)
        k8 = nsd.nsd_quantize_int8(x, key, 2.0).k
        bm_kernel, _ = bitmap_pack_blocked(k8, interpret=interpret)
        bm_wf = wireformat.pack_bitmap(k8)
        np.testing.assert_array_equal(np.asarray(bm_kernel),
                                      np.asarray(bm_wf))


class TestRing:
    def test_ring_matches_dense_mean_within_bound(self, key):
        """Compressed N=4 ring all-reduce vs dense average, within the
        documented NSD bound (acceptance criterion)."""
        n = 4
        gs = jnp.stack([
            jax.random.normal(jax.random.fold_in(key, i), (1000,))
            for i in range(n)])
        mean, tele = ring_allreduce_nsd(gs, key, RingConfig(s=1.0))
        dense = jnp.mean(gs, axis=0)
        err = float(jnp.max(jnp.abs(mean - dense)))
        stat_utils.assert_within_bound(err, tele.error_bound)

    def test_ring_wire_under_25pct_at_paper_sparsity(self, key):
        """At the ~92% sparsity operating point the whole exchange must be
        <= 25% of a dense f32 ring (acceptance criterion)."""
        n = 4
        gs = jnp.stack([
            jax.random.normal(jax.random.fold_in(key, i), (64, 64))
            for i in range(n)])
        s = 8.0
        sp = float(jnp.mean(nsd.nsd_quantize(gs[0], key, s) == 0))
        assert sp > 0.88, sp
        _, tele = ring_allreduce_nsd(gs, key, RingConfig(s=s))
        assert float(tele.ratio) <= 0.25, float(tele.ratio)

    def test_ring_error_shrinks_with_smaller_s(self, key):
        n = 4
        gs = jnp.stack([
            jax.random.normal(jax.random.fold_in(key, i), (512,))
            for i in range(n)])
        dense = jnp.mean(gs, axis=0)
        errs = {}
        for s in (0.25, 4.0):
            mean, _ = ring_allreduce_nsd(gs, key, RingConfig(s=s))
            errs[s] = float(jnp.max(jnp.abs(mean - dense)))
        assert errs[0.25] < errs[4.0], errs

    def test_single_node_is_exact_and_free(self, key):
        g = jax.random.normal(key, (7, 11))[None]
        mean, tele = ring_allreduce_nsd(g, key)
        np.testing.assert_array_equal(np.asarray(mean), np.asarray(g[0]))
        assert float(tele.wire_bytes) == 0.0

    def test_ring_is_deterministic(self, key):
        gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (256,))
                        for i in range(4)])
        m1, _ = ring_allreduce_nsd(gs, key)
        m2, _ = ring_allreduce_nsd(gs, key)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


class TestCommPolicy:
    def _grads(self, key):
        return {
            "dense_layer": {"w": jax.random.normal(key, (64, 64)) * 0.01,
                            "b": jax.random.normal(key, (64,)) * 0.01},
            "head": {"w": jax.random.normal(
                jax.random.fold_in(key, 1), (64, 32)) * 0.01},
        }

    def test_small_leaves_stay_dense(self, key):
        grads = self._grads(key)
        pol = CommPolicy(default="nsd", min_leaf_size=256)
        out, _, tele = compress_tree(grads, key, pol)
        # the 64-elem bias is below min_leaf_size -> exact passthrough
        np.testing.assert_array_equal(
            np.asarray(out["dense_layer"]["b"]),
            np.asarray(grads["dense_layer"]["b"]))
        assert int(tele["wire_bytes"]) < int(tele["dense_bytes"])

    def test_overrides_win(self, key):
        grads = self._grads(key)
        pol = CommPolicy(default="nsd", overrides=(("head", "dense"),))
        out, _, _ = compress_tree(grads, key, pol)
        np.testing.assert_array_equal(np.asarray(out["head"]["w"]),
                                      np.asarray(grads["head"]["w"]))

    def test_nsd_leaves_equal_wire_roundtrip(self, key):
        grads = self._grads(key)
        pol = CommPolicy(default="nsd", s=2.0, min_leaf_size=1)
        out, _, _ = compress_tree(grads, key, pol)
        w = grads["dense_layer"]["w"]
        stat_utils.assert_within_bound(
            jnp.max(jnp.abs(out["dense_layer"]["w"] - w)),
            nsd.compute_delta(w, 2.0))

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            CommPolicy(default="gzip")

    def test_collect_stats_routes_to_sink(self, key):
        statslib.reset()
        grads = self._grads(key)
        pol = CommPolicy(default="nsd", collect_stats=True,
                         stats_tag="testcomm/")
        compress_tree(grads, key, pol)
        jax.effects_barrier()
        summ = statslib.comm_summary()
        assert "testcomm/" in summ and summ["testcomm/"]["wire_bytes"] > 0


class TestErrorFeedback:
    def test_residual_conservation(self, key):
        """sent + residual == g + residual_in exactly, every round — the
        invariant that survives the migration out of ssgd.py."""
        g = jax.random.normal(key, (512,))
        state = None
        for _ in range(10):
            sent, new_state = topk_error_feedback(g, state, k_frac=0.05)
            carried_in = (state.residual if state is not None
                          else jnp.zeros(512))
            np.testing.assert_allclose(
                np.asarray(sent.reshape(-1) + new_state.residual),
                np.asarray(g + carried_in), rtol=1e-6, atol=1e-6)
            state = new_state

    def test_ssgd_reexport_is_same_function(self):
        from repro.comm import compression
        from repro.distributed import ssgd
        assert ssgd.topk_error_feedback is compression.topk_error_feedback
        assert ssgd.ErrorFeedbackState is compression.ErrorFeedbackState

    def test_topk_ef_through_policy_recovers_mass(self, key):
        g = {"w": jax.random.normal(key, (512,))}
        pol = CommPolicy(default="topk_ef", topk_frac=0.05, min_leaf_size=1)
        states = init_comm_state(g, pol)
        assert set(states) == {"w"}
        sent_total = jnp.zeros((512,))
        for _ in range(50):
            out, states, _ = compress_tree(g, key, pol, states)
            sent_total = sent_total + out["w"]
        rel = float(jnp.linalg.norm(sent_total / 50 - g["w"])
                    / jnp.linalg.norm(g["w"]))
        assert rel < 0.3, rel


class TestIntegration:
    def test_ssgd_step_with_comm_policy(self, key):
        from repro.configs import get_smoke_model
        from repro.core import DitherPolicy
        from repro.distributed import SSGDConfig, make_ssgd_step, shard_batch
        from repro.optim import OptConfig, init_opt_state

        model = get_smoke_model("mamba2-370m")
        params, _ = model.init(key)
        batch = {
            "tokens": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
        }
        opt = OptConfig(lr=1e-3)
        dcfg = SSGDConfig(n_nodes=4, s_schedule="sqrt", s_base=1.0)
        step_fn, _ = make_ssgd_step(
            model, opt, dcfg, DitherPolicy(variant="paper"),
            comm_policy=CommPolicy(default="nsd", s=1.0))
        state = init_opt_state(params, opt)
        p2, s2, m, _ = step_fn(params, state, shard_batch(batch, 4), key)
        assert float(m["loss"]) > 0
        wire, dense = float(m["comm_wire_bytes"]), float(m["comm_dense_bytes"])
        assert 0 < wire < dense, (wire, dense)

    def test_trainer_with_comm_policy_still_learns(self, key):
        from repro.configs import get_smoke_model
        from repro.data import TokenStreamConfig, token_batch
        from repro.optim import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig

        model = get_smoke_model("mamba2-370m")
        tscfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=8)
        trainer = Trainer(
            model, OptConfig(lr=1e-3),
            TrainerConfig(total_steps=12, log_every=4),
            comm_policy=CommPolicy(default="nsd", s=0.5))
        out = trainer.fit(iter(token_batch(tscfg, i) for i in range(200)))
        hist = out["history"]
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.05, hist

    def test_trainer_ef_state_survives_checkpoint_resume(self, key, tmp_path):
        """topk_ef residuals ride in the checkpoint tree: a restored
        trainer continues from the saved error-feedback state."""
        from repro.configs import get_smoke_model
        from repro.data import TokenStreamConfig, token_batch
        from repro.optim import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig

        model = get_smoke_model("mamba2-370m")
        tscfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=8)
        pol = CommPolicy(default="topk_ef", topk_frac=0.1, min_leaf_size=1)

        def make(total):
            return Trainer(model, OptConfig(lr=1e-3),
                           TrainerConfig(total_steps=total, log_every=0,
                                         ckpt_every=3, ckpt_dir=str(tmp_path)),
                           comm_policy=pol)

        t1 = make(3)
        t1.fit(iter(token_batch(tscfg, i) for i in range(100)))
        saved = {k: np.asarray(v.residual)
                 for k, v in t1._comm_state.items()}
        assert saved and any(np.abs(r).sum() > 0 for r in saved.values())

        t2 = make(6)
        params, opt_state, _ = t2.restore_or_init(key)
        assert int(opt_state["step"]) == 3
        for name, r in saved.items():
            np.testing.assert_array_equal(
                np.asarray(t2._comm_state[name].residual), r)

    def test_s_for_n_sqrt_is_python_float(self):
        from repro.distributed import SSGDConfig
        s = SSGDConfig(n_nodes=4, s_schedule="sqrt", s_base=2.0).s_for_n()
        assert isinstance(s, float) and not isinstance(s, jax.Array)
        assert s == pytest.approx(4.0)


SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax, jax.numpy as jnp
    from repro.comm import (RingConfig, make_ring_allreduce,
                            ring_allreduce_nsd)
    mesh = jax.make_mesh((8,), ("nodes",))
    key = jax.random.PRNGKey(0)
    gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (37, 13))
                    for i in range(8)])
    fn = make_ring_allreduce(mesh, "nodes", RingConfig(s=1.0))
    means, wires, bounds = fn(gs, key)
    # the sim is jitted for the comparison: eager XLA fuses elementwise
    # chains differently (1-ulp FMA artifacts); per-hop math is identical
    sim = jax.jit(functools.partial(ring_allreduce_nsd, cfg=RingConfig(s=1.0)))
    sim_mean, tele = sim(gs, key)
    # every node must hold the identical result...
    for i in range(1, 8):
        assert float(jnp.max(jnp.abs(means[i] - means[0]))) == 0.0
    # ...bit-exactly equal to the simulation (same hop math, same keys;
    # each hop's output is the next hop's input, so final-state equality
    # transitively pins every intermediate hop)
    assert float(jnp.max(jnp.abs(means[0] - sim_mean))) == 0.0
    assert float(jnp.sum(wires)) == float(tele.wire_bytes)
    # per-hop delta accounting must agree with the sim's error bound too
    assert abs(float(bounds[0]) - float(tele.error_bound)) < 1e-6
    # dispatcher: telemetry populated and node-count mismatch rejected
    from repro.comm import allreduce_compressed
    mean_d, tele_d = allreduce_compressed(gs, key, RingConfig(s=1.0),
                                          mesh=mesh, axis_name="nodes")
    assert float(jnp.max(jnp.abs(mean_d - sim_mean))) == 0.0
    assert float(tele_d.dense_bytes) == float(tele.dense_bytes)
    assert float(tele_d.error_bound) > 0.0
    assert tele_d.packs_per_segment == 8
    try:
        allreduce_compressed(gs[:3], key, mesh=mesh, axis_name="nodes")
    except ValueError:
        pass
    else:
        raise AssertionError("node/mesh mismatch not rejected")
    print("SHARDMAP_RING_OK", float(jnp.sum(wires)))
""")


def test_shardmap_ring_subprocess():
    """The real compressed exchange: packed NSD pytrees cross (virtual)
    device boundaries via ppermute and agree with the simulation."""
    env = dict(os.environ, PYTHONPATH=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", SHARDMAP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SHARDMAP_RING_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 (virtual) devices — run under "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8 (the CI comm job does)")
def test_ring_shardmap_inprocess(key):
    """In-process sim-vs-shard_map differential for the multi-device CI
    job: bit-exact mean, identical wire bytes and per-hop Delta sums."""
    import functools

    from repro.comm import make_ring_allreduce
    mesh = jax.make_mesh((8,), ("nodes",))
    gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (129,))
                    for i in range(8)])
    means, wires, bounds = make_ring_allreduce(
        mesh, "nodes", RingConfig(s=1.0))(gs, key)
    sim_mean, tele = jax.jit(functools.partial(
        ring_allreduce_nsd, cfg=RingConfig(s=1.0)))(gs, key)
    assert float(jnp.max(jnp.abs(means[0] - sim_mean))) == 0.0
    assert float(jnp.sum(wires)) == float(tele.wire_bytes)
    assert abs(float(bounds[0]) - float(tele.error_bound)) < 1e-6
