"""Residual-memory suite: the `repro.memory` acceptance gates.

Four claims, gated on every PR:

* **roundtrip** — the ``nsd`` residual codec is BIT-EXACT against the
  ``repro.core.nsd`` reference for the same key (the only loss is the
  unbiased NSD quantization itself; zero-width band), including
  non-chunk-multiple shapes; the ``int8`` affine per-row codec's
  reconstruction error stays within its characterized scale/2 bound.
* **compression** — training LeNet-300-100 with NSD-coded residuals, the
  measured residual bytes (occupancy-aware, summed over the dithered
  layers and all steps) compress >= 3.5x vs the dense fp32 store
  (``meets_3p5_floor`` is a hard zero-band gate on that floor, on top of
  the banded ratio itself); the int8 ratio is banded alongside, and so is
  the HBM-resident *capacity* ratio (what the live buffers actually
  shrink by — see repro.quant on measured vs capacity).
* **convergence** — int8- and NSD-residual training lands within the
  committed accuracy band of fp32-residual training on the same harness
  (the paper's thesis extended to the saved activations: only the
  weight-gradient product ``dW = x^T . g~`` sees the reconstruction).
* **remat_vs_store** — recompute-in-VJP vs encode/decode step timing,
  recorded UNGATED (wall clock on shared runners is noise).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy
from repro.obs import metrics as statslib
from repro.quant import (DEFAULT_NSD_S, decode, encode, nsd_fakequant,
                         resid_key)

from benchmarks.harness import train_classifier

# (arm name, --memory-program spec); fp32 is the parity/reference arm
ARMS = (("fp32", None), ("nsd", "default=nsd"), ("int8", "default=int8"),
        ("remat", "default=remat"))


def roundtrip_metrics(seed: int = 0) -> Dict[str, float]:
    """Deterministic codec checks (no training)."""
    key = jax.random.PRNGKey(seed)
    out: Dict[str, float] = {}
    # relu-activation-like tensor on a chunk multiple, and an odd shape
    # that exercises the wire format's padding path
    for i, (label, shape) in enumerate((("nsd_max_abs_diff", (64, 256)),
                                        ("nsd_oddshape_max_abs_diff",
                                         (7, 93)))):
        kx = jax.random.fold_in(key, i)
        x = jax.nn.relu(jax.random.normal(kx, shape, jnp.float32))
        kr = resid_key(jax.random.fold_in(kx, 1))
        dec = decode("nsd", encode("nsd", x, kr))
        ref = nsd_fakequant(x, kr, DEFAULT_NSD_S)
        out[label] = float(jnp.max(jnp.abs(dec - ref)))
    x = jax.random.normal(jax.random.fold_in(key, 7), (32, 128)) * 3.0
    enc = encode("int8", x, key)
    err = jnp.abs(decode("int8", enc) - x).reshape(-1, x.shape[-1])
    out["int8_err_over_bound"] = float(jnp.max(err / (enc.scale / 2.0)))
    return out


def run(quick: bool = True) -> Dict[str, Dict]:
    steps = 40 if quick else 120
    model = pm.lenet300100()
    arms: Dict[str, Dict[str, float]] = {}
    for name, mem in ARMS:
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag=f"mb{name}/")
        res = train_classifier(model, pol, steps=steps, memory=mem)
        # harness resets the sink per run: snapshot the compression now
        res["compression_x"] = statslib.overall_residual_compression(
            f"mb{name}/")
        res["capacity_compression_x"] = statslib.overall_residual_compression(
            f"mb{name}/", capacity=True)
        arms[name] = res
    return {"arms": arms, "roundtrip": roundtrip_metrics()}


def bench(quick: bool = True) -> List[BenchResult]:
    out = run(quick=quick)
    arms, rt = out["arms"], out["roundtrip"]
    nsd_x = arms["nsd"]["compression_x"]
    results = [
        BenchResult(
            name="memory_bench/roundtrip",
            value=0.0, unit="us",
            derived=dict(rt),
            gates={
                # the acceptance bar: pack->unpack == the nsd reference,
                # bit for bit — zero-width bands
                "nsd_max_abs_diff": Gate(abs=0.0, direction="both"),
                "nsd_oddshape_max_abs_diff": Gate(abs=0.0, direction="both"),
                # characterized bound: error/(scale/2) <= 1 (+fp headroom)
                "int8_err_over_bound": Gate(abs=0.05, direction="high"),
            },
        ),
        BenchResult(
            name="memory_bench/compression",
            value=arms["nsd"]["us_per_step"], unit="us/step",
            derived={
                "nsd_compression_x": nsd_x,
                "nsd_capacity_compression_x":
                    arms["nsd"]["capacity_compression_x"],
                "int8_compression_x": arms["int8"]["compression_x"],
                "fp32_compression_x": arms["fp32"]["compression_x"],
                # hard floor from the issue: >= 3.5x on the dithered layers
                "meets_3p5_floor": 1.0 if nsd_x >= 3.5 else 0.0,
            },
            gates={
                "nsd_compression_x": Gate(rel=0.10, direction="low"),
                "nsd_capacity_compression_x": Gate(rel=0.05,
                                                   direction="low"),
                "int8_compression_x": Gate(rel=0.05, direction="low"),
                "fp32_compression_x": Gate(abs=0.0, direction="both"),
                "meets_3p5_floor": Gate(abs=0.0, direction="both"),
            },
        ),
    ]
    base = arms["fp32"]
    for name in ("fp32", "nsd", "int8"):
        r = arms[name]
        results.append(BenchResult(
            name=f"memory_bench/convergence_{name}",
            value=r["us_per_step"], unit="us/step",
            derived={"acc": r["acc"], "final_loss": r["final_loss"],
                     "dacc": r["acc"] - base["acc"],
                     "sparsity": r["sparsity"]},
            gates={"acc": Gate(abs=10.0, direction="low"),
                   "dacc": Gate(abs=8.0, direction="low")},
        ))
    results.append(BenchResult(
        name="memory_bench/remat_vs_store",
        value=arms["remat"]["us_per_step"], unit="us/step",
        derived={
            "remat_us_per_step": arms["remat"]["us_per_step"],
            "store_nsd_us_per_step": arms["nsd"]["us_per_step"],
            "fp32_us_per_step": base["us_per_step"],
            "remat_over_store": (arms["remat"]["us_per_step"]
                                 / max(arms["nsd"]["us_per_step"], 1e-9)),
            "remat_acc": arms["remat"]["acc"],
        },
        # timing contrast: recorded for the trajectory, never gated
        gates={},
    ))
    return results


if __name__ == "__main__":
    for r in bench(quick=True):
        print(r.name, f"{r.value:.1f}{r.unit}", r.derived_str())
