"""Paper §3.4: theoretical computational savings of the dithered dot
products — comp.savings = O(1/m + p_nz) — evaluated with MEASURED p_nz per
model, plus the paper's projected hardware gains (SCNN-class accelerators,
x1.5-x8 at 75-95 % sparsity) and this repo's TPU-native equivalents.
"""
from __future__ import annotations

from typing import List

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy

from benchmarks.harness import train_classifier


def bench(quick: bool = True) -> List[BenchResult]:
    out = []
    for name, factory in (("mlp-mnist", lambda: pm.mlp_mnist(hidden=(500, 500))),
                          ("lenet5", pm.lenet5)):
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag=f"cx/{name}/")
        r = train_classifier(factory(), pol, steps=20 if quick else 60)
        p_nz = 1.0 - r["sparsity"] / 100.0
        # paper eq. 12 with m >> 1: savings ratio ~ p_nz (fraction of MACs
        # left). Dense-equivalent speedup on sparsity hardware = 1/p_nz.
        ideal = 1.0 / max(p_nz, 1e-6)
        # TPU-native equivalents implemented here: int8 MXU backward (2x)
        # and, when sparsity is row-structured, contraction-dim shrink
        out.append(BenchResult(
            name=f"complexity/{name}", value=r["us_per_step"],
            unit="us/step",
            derived={"p_nz": p_nz, "ideal_sparse_speedup": ideal,
                     "tpu_int8_bwd": 2.0},
            gates={"p_nz": Gate(abs=0.08, direction="high")},
            context={"note": "paper cites x1.5-x8 on SCNN at this range"}))
    return out
