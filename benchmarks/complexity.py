"""Paper §3.4: theoretical computational savings of the dithered dot
products — comp.savings = O(1/m + p_nz) — evaluated with MEASURED p_nz per
model, plus the paper's projected hardware gains (SCNN-class accelerators,
x1.5-x8 at 75-95 % sparsity) and this repo's TPU-native equivalents.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core import DitherPolicy
from repro.configs import paper_models as pm

from benchmarks.harness import train_classifier


def bench(quick: bool = True) -> List[Tuple[str, float, str]]:
    out = []
    for name, factory in (("mlp-mnist", lambda: pm.mlp_mnist(hidden=(500, 500))),
                          ("lenet5", pm.lenet5)):
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag=f"cx/{name}/")
        r = train_classifier(factory(), pol, steps=20 if quick else 60)
        p_nz = 1.0 - r["sparsity"] / 100.0
        # paper eq. 12 with m >> 1: savings ratio ~ p_nz (fraction of MACs
        # left). Dense-equivalent speedup on sparsity hardware = 1/p_nz.
        ideal = 1.0 / max(p_nz, 1e-6)
        # TPU-native equivalents implemented here: int8 MXU backward (2x)
        # and, when sparsity is row-structured, contraction-dim shrink
        tpu_int8 = 2.0
        out.append((
            f"complexity/{name}", r["us_per_step"],
            f"p_nz={p_nz:.3f} ideal_sparse_speedup=x{ideal:.1f} "
            f"(paper cites x1.5-x8 on SCNN at this range) "
            f"tpu_int8_bwd=x{tpu_int8:.1f} structural"))
    return out
