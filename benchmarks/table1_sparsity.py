"""Paper Table 1: accuracy + pre-activation-gradient sparsity for
{Baseline, Dithered, 8-bit, 8-bit+Dithered} across the paper's models
(on the synthetic offline stand-in datasets; see EXPERIMENTS.md)."""
from __future__ import annotations

from typing import Dict, List

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy

from benchmarks.harness import measure_baseline_sparsity, train_classifier

QUICK_MODELS = ("mlp-mnist", "lenet300100", "lenet5")
FULL_MODELS = QUICK_MODELS + ("alexnet-c10", "vgg11-c10", "resnet18-c10")


def _model(name: str):
    return {
        "mlp-mnist": lambda: pm.mlp_mnist(hidden=(500, 500)),
        "lenet300100": pm.lenet300100,
        "lenet5": pm.lenet5,
        "alexnet-c10": pm.alexnet_cifar,
        "vgg11-c10": pm.vgg11_cifar,
        "resnet18-c10": pm.resnet18_cifar,
    }[name]()


def run(quick: bool = True, steps: int = 50) -> List[Dict]:
    rows = []
    names = QUICK_MODELS if quick else FULL_MODELS
    for name in names:
        model = _model(name)
        base_sp = measure_baseline_sparsity(model, steps=3)
        res_base = train_classifier(model, None, steps=steps)
        methods = {
            "dithered": DitherPolicy(variant="paper", s=2.0,
                                     collect_stats=True, stats_tag=f"{name}/d/"),
            "int8+dith": DitherPolicy(variant="int8", s=2.0,
                                      collect_stats=True,
                                      stats_tag=f"{name}/i/"),
        }
        row = {
            "model": name,
            "baseline_acc": res_base["acc"],
            "baseline_sparsity": base_sp,
            "us_per_step_baseline": res_base["us_per_step"],
        }
        for mname, pol in methods.items():
            r = train_classifier(model, pol, steps=steps)
            row[f"{mname}_acc"] = r["acc"]
            row[f"{mname}_sparsity"] = r.get("sparsity", float("nan"))
            row[f"{mname}_bits"] = r.get("max_bits", float("nan"))
            row[f"us_per_step_{mname}"] = r["us_per_step"]
        rows.append(row)
    return rows


def bench(quick: bool = True) -> List[BenchResult]:
    """BenchResults for benchmarks.suite — one per Table-1 model row.

    Gated: accuracies must not drop (the paper's parity claim) and induced
    sparsity must not collapse (the paper's efficiency claim). Bands cover
    seed/platform jitter of a ~50-step synthetic run; timing is recorded
    but never gated.
    """
    out = []
    for row in run(quick=quick):
        out.append(BenchResult(
            name=f"table1/{row['model']}",
            value=row["us_per_step_dithered"],
            unit="us/step",
            derived={
                "baseline_acc": row["baseline_acc"],
                "dithered_acc": row["dithered_acc"],
                "int8_dith_acc": row["int8+dith_acc"],
                "baseline_sparsity": row["baseline_sparsity"],
                "dithered_sparsity": row["dithered_sparsity"],
                "int8_dith_sparsity": row["int8+dith_sparsity"],
                "dithered_bits": row["dithered_bits"],
                "us_per_step_baseline": row["us_per_step_baseline"],
            },
            gates={
                "dithered_acc": Gate(abs=10.0, direction="low"),
                "int8_dith_acc": Gate(abs=10.0, direction="low"),
                "dithered_sparsity": Gate(abs=8.0, direction="low"),
                "int8_dith_sparsity": Gate(abs=8.0, direction="low"),
                "dithered_bits": Gate(abs=1.0, direction="high"),
            },
        ))
    return out
