"""Paper Table 1: accuracy + pre-activation-gradient sparsity for
{Baseline, Dithered, 8-bit, 8-bit+Dithered} across the paper's models
(on the synthetic offline stand-in datasets; see EXPERIMENTS.md)."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import paper_models as pm
from repro.core import DitherPolicy

from benchmarks.harness import measure_baseline_sparsity, train_classifier

QUICK_MODELS = ("mlp-mnist", "lenet300100", "lenet5")
FULL_MODELS = QUICK_MODELS + ("alexnet-c10", "vgg11-c10", "resnet18-c10")


def _model(name: str):
    return {
        "mlp-mnist": lambda: pm.mlp_mnist(hidden=(500, 500)),
        "lenet300100": pm.lenet300100,
        "lenet5": pm.lenet5,
        "alexnet-c10": pm.alexnet_cifar,
        "vgg11-c10": pm.vgg11_cifar,
        "resnet18-c10": pm.resnet18_cifar,
    }[name]()


def run(quick: bool = True, steps: int = 50) -> List[Dict]:
    rows = []
    names = QUICK_MODELS if quick else FULL_MODELS
    for name in names:
        model = _model(name)
        base_sp = measure_baseline_sparsity(model, steps=3)
        res_base = train_classifier(model, None, steps=steps)
        methods = {
            "dithered": DitherPolicy(variant="paper", s=2.0,
                                     collect_stats=True, stats_tag=f"{name}/d/"),
            "int8+dith": DitherPolicy(variant="int8", s=2.0,
                                      collect_stats=True,
                                      stats_tag=f"{name}/i/"),
        }
        row = {
            "model": name,
            "baseline_acc": res_base["acc"],
            "baseline_sparsity": base_sp,
            "us_per_step_baseline": res_base["us_per_step"],
        }
        for mname, pol in methods.items():
            r = train_classifier(model, pol, steps=steps)
            row[f"{mname}_acc"] = r["acc"]
            row[f"{mname}_sparsity"] = r.get("sparsity", float("nan"))
            row[f"{mname}_bits"] = r.get("max_bits", float("nan"))
            row[f"us_per_step_{mname}"] = r["us_per_step"]
        rows.append(row)
    return rows


def bench(quick: bool = True):
    """CSV rows for benchmarks.run: name,us_per_call,derived."""
    out = []
    for row in run(quick=quick):
        derived = (f"acc_base={row['baseline_acc']:.1f}%"
                   f" acc_dith={row['dithered_acc']:.1f}%"
                   f" sp_base={row['baseline_sparsity']:.1f}%"
                   f" sp_dith={row['dithered_sparsity']:.1f}%"
                   f" bits={row['dithered_bits']:.0f}"
                   f" acc_8bit_dith={row['int8+dith_acc']:.1f}%"
                   f" sp_8bit_dith={row['int8+dith_sparsity']:.1f}%")
        out.append((f"table1/{row['model']}",
                    row["us_per_step_dithered"], derived))
    return out
