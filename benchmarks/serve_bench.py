"""Serving-tier benchmark: throughput, latency, KV bytes, decode parity.

A synthetic heavy-traffic trace (staggered arrivals, mixed prompt and
generation lengths) drives the chunked-prefill engine on the gemma-2b
smoke config, one arm per KV layout:

* ``dense``  — per-slot fp32 buffers from ``Model.init_cache``.
* ``fp32``   — paged pool, fp32 passthrough pages. Gated BIT-EXACT (token
  level) against the ``greedy_generate`` reference: paging and chunked
  prefill are layout changes, not numerics changes.
* ``int8`` / ``nsd`` — quantized pages. Gated on a bounded
  token-disagreement fraction vs the reference plus a >= 3x capacity
  compression floor from the dual byte accounting on the ``serve`` stream
  (encoded page capacity vs the dense fp32 counterfactual).
* ``preempt`` — fp32 pages on a pool sized to force
  preemption-and-recompute churn; gated on full completion AND bit-exact
  outputs, so eviction is a performance event, never a correctness one.

Wall-clock derived metrics (tokens/sec, p99 tick latency) carry wide
bands — CI hosts are noisy and the model is tiny; the tight gates are the
parity, completion, and byte-accounting invariants.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.bench import BenchResult, Gate
from repro.configs import get_smoke_model
from repro.obs.bus import MetricsBus, set_bus
from repro.serve import Engine, Request, ServeConfig, greedy_generate


def _trace(vocab: int, n_requests: int, seed: int = 0
           ) -> List[Tuple[np.ndarray, int, int]]:
    """(prompt, max_new, arrival_tick) synthetic trace: bursty arrivals,
    prompt lengths 3..24, generation lengths 4..16."""
    rng = np.random.default_rng(seed)
    out = []
    tick = 0
    for _ in range(n_requests):
        plen = int(rng.integers(3, 25))
        nnew = int(rng.integers(4, 17))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        out.append((prompt, nnew, tick))
        if rng.random() < 0.4:  # burst boundary
            tick += int(rng.integers(1, 4))
    return out


def _drive(model, params, cfg: ServeConfig, trace, max_ticks: int):
    """Feed the trace by arrival tick; returns (results, tick_times_s,
    engine). Requests not admitted by the queue bound are dropped (the
    trace here is sized to never trip it)."""
    eng = Engine(model, params, cfg, name=f"bench-{cfg.kv_mode}"
                 f"{'-paged' if cfg.kv_page else ''}")
    results: Dict[int, List[int]] = {}
    times: List[float] = []
    pending = sorted(range(len(trace)), key=lambda i: trace[i][2])
    cursor = 0
    for tick in range(max_ticks):
        while cursor < len(pending) and trace[pending[cursor]][2] <= tick:
            uid = pending[cursor]
            prompt, nnew, _ = trace[uid]
            assert eng.submit(Request(uid, prompt, max_new_tokens=nnew))
            cursor += 1
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
        results.update(eng._finished)
        eng._finished = {}
        if (cursor == len(pending) and eng.sched.queue_depth == 0
                and all(s is None for s in eng._slots)):
            break
    return results, times, eng


def _kv_bytes_per_token(bus: MetricsBus, tag: str) -> Tuple[float, float]:
    """(kv_bytes per generated token, capacity compression x) from the
    serve stream rows: mean live KV capacity over busy ticks divided by
    mean live tokens is noisy, so integrate byte-ticks / token-ticks."""
    rows = bus.rows_since("serve", tag, 0)
    busy = rows[rows[:, 1] > 0]  # active_slots > 0
    gen = float(busy[:, 4].sum())
    byte_ticks = float(busy[:, 5].sum())
    dense_ticks = float(busy[:, 6].sum())
    per_tok = byte_ticks / max(gen, 1.0)
    comp = dense_ticks / max(byte_ticks, 1.0)
    return per_tok, comp


def bench(quick: bool = True) -> List[BenchResult]:
    n_requests = 24 if quick else 96
    max_ticks = 4000
    model = get_smoke_model("gemma-2b")
    params, _ = model.init(jax.random.PRNGKey(0))
    vocab = model.cfg.vocab
    trace = _trace(vocab, n_requests)

    refs = {uid: greedy_generate(model, params, p, n, max_len=64)
            for uid, (p, n, _) in enumerate(trace)}
    total_ref_tokens = sum(len(v) for v in refs.values())

    arms = {
        "dense": ServeConfig(max_batch=8, max_len=64, chunk=8),
        "fp32": ServeConfig(max_batch=8, max_len=64, chunk=8,
                            kv_mode="fp32", kv_page=16),
        "int8": ServeConfig(max_batch=8, max_len=64, chunk=8,
                            kv_mode="int8", kv_page=16),
        "nsd": ServeConfig(max_batch=8, max_len=64, chunk=8,
                           kv_mode="nsd", kv_page=16),
        # pool sized for ~2.5 of 8 slots at worst case -> forced eviction
        "preempt": ServeConfig(max_batch=8, max_len=64, chunk=8,
                               kv_mode="fp32", kv_page=8, kv_pool_pages=14),
    }

    out: List[BenchResult] = []
    for arm, cfg in arms.items():
        bus = MetricsBus()
        set_bus(bus)
        try:
            t0 = time.perf_counter()
            results, times, eng = _drive(model, params, cfg, trace,
                                         max_ticks)
            wall = time.perf_counter() - t0
        finally:
            set_bus(None)

        done_tokens = sum(len(v) for v in results.values())
        completed = len(results) / len(trace)
        mism = sum(1 for uid, toks in results.items()
                   for a, b in zip(toks, refs[uid]) if a != b)
        disagree = mism / max(total_ref_tokens, 1)
        tok_s = done_tokens / max(wall, 1e-9)
        # first ticks are dominated by jit compilation of the two step
        # variants (prefill chunk + single-token); latency is gated on the
        # steady state
        steady = times[10:] if len(times) > 20 else times
        p99_ms = float(np.percentile(np.asarray(steady), 99) * 1e3)
        per_tok, comp = _kv_bytes_per_token(bus, eng.name)

        derived = {
            "completed_frac": completed,
            "token_disagree_frac": disagree,
            "tokens_per_sec": tok_s,
            "p99_tick_ms": p99_ms,
            "kv_bytes_per_token": per_tok,
        }
        gates = {
            # every request must finish within the tick budget
            "completed_frac": Gate(abs=0.0, direction="both"),
            # throughput/latency recorded with wide noise-safe bands
            "tokens_per_sec": Gate(rel=0.90, direction="low"),
            "p99_tick_ms": Gate(rel=9.0, direction="high"),
            # byte accounting is deterministic: tight relative band
            "kv_bytes_per_token": Gate(rel=0.02, abs=1.0,
                                       direction="high"),
        }
        if arm in ("dense", "fp32", "preempt"):
            # layout changes only: token-level bit-exact vs the reference
            gates["token_disagree_frac"] = Gate(abs=0.0, direction="both")
        else:
            # quantized pages flip near-tie argmaxes — pervasive on a
            # random-init smoke model whose logit gaps are tiny, so the
            # absolute damage bound is per-codec (the sparsifying NSD
            # format is far more aggressive than affine int8) and drift
            # beyond the committed baseline is gated separately
            bound = {"int8": 0.15, "nsd": 0.60}[arm]
            derived["disagree_bounded"] = 1.0 if disagree <= bound else 0.0
            gates["disagree_bounded"] = Gate(abs=0.0, direction="both")
            gates["token_disagree_frac"] = Gate(abs=0.10, direction="high")
        if cfg.kv_page and arm in ("int8", "nsd"):
            derived["kv_capacity_x"] = comp
            derived["meets_3x_floor"] = 1.0 if comp >= 3.0 else 0.0
            gates["meets_3x_floor"] = Gate(abs=0.0, direction="both")
            gates["kv_capacity_x"] = Gate(rel=0.02, direction="low")
        if arm == "preempt":
            derived["preemptions"] = float(eng.preemptions)
            derived["preempted_any"] = 1.0 if eng.preemptions > 0 else 0.0
            gates["preempted_any"] = Gate(abs=0.0, direction="both")

        out.append(BenchResult(
            name=f"serve/{arm}",
            value=wall * 1e6,
            derived=derived,
            gates=gates,
            context={"requests": len(trace), "model": "gemma-2b-smoke",
                     "kv_mode": cfg.kv_mode, "kv_page": cfg.kv_page,
                     "pool_pages": cfg.kv_pool_pages,
                     "chunk": cfg.chunk, "quick": quick},
        ))
    return out


if __name__ == "__main__":
    for r in bench(quick=True):
        print(r.name, f"{r.value:.0f}us", r.derived)
