"""Paper fig. 3/.7/.8: convergence curves — dithered backprop must track the
baseline loss trajectory (no slowdown in epochs/steps)."""
from __future__ import annotations

from typing import Dict, List

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy

from benchmarks.harness import train_classifier


def run(steps: int = 80) -> List[Dict]:
    model = pm.lenet5()
    rows = []
    for name, pol in (
        ("baseline", None),
        ("dithered", DitherPolicy(variant="paper", s=2.0)),
        ("8bit+dith", DitherPolicy(variant="int8", s=2.0)),
    ):
        r = train_classifier(model, pol, steps=steps)
        rows.append({"method": name, "acc": r["acc"],
                     "final_loss": r["final_loss"],
                     "us_per_step": r["us_per_step"]})
    return rows


def bench(quick: bool = True) -> List[BenchResult]:
    """The convergence-parity claim as a gate: each method's accuracy gap
    to the in-run baseline (``dacc``) must not open up."""
    rows = run(steps=40 if quick else 120)
    base = next(r for r in rows if r["method"] == "baseline")
    out = []
    for r in rows:
        out.append(BenchResult(
            name=f"fig3/{r['method']}",
            value=r["us_per_step"],
            unit="us/step",
            derived={"acc": r["acc"], "final_loss": r["final_loss"],
                     "dacc": r["acc"] - base["acc"]},
            gates={"acc": Gate(abs=10.0, direction="low"),
                   "dacc": Gate(abs=8.0, direction="low")},
        ))
    return out
