"""§Perf hillclimb driver: run one (arch x shape) cell under a named list of
variants (sharding-rule mutations, dither policy, model config patches),
and print the before/after roofline terms + per-collective breakdown.

Each variant is a HYPOTHESIS about the dominant roofline term; the output
is the 'measure' step of the hypothesis -> change -> measure -> validate
loop recorded in EXPERIMENTS.md §Perf.

Run as a module *only from a fresh process* (it imports repro.launch.dryrun
which pins 512 host devices):

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen2.5-32b:train_4k
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional


def variants_for(arch: str, shape: str) -> Dict[str, dict]:
    """Named variant registry. Keys map to EXPERIMENTS.md §Perf entries."""
    from repro.core.policy import DitherPolicy
    from repro.launch.dryrun import make_rules

    V: Dict[str, dict] = {"baseline(paper)": {}}

    V["dither-off"] = {"policy": None}
    V["dither-int8-bwd"] = {"policy": DitherPolicy(variant="int8", s=2.0)}
    V["dither-row"] = {"policy": DitherPolicy(variant="row")}

    # sharding mutations
    def rules_seqshard(mesh, case, arch_id):
        r = make_rules(mesh, case, arch_id)
        r.mapping["cache_seq"] = "model"
        return r

    def rules_fsdp(mesh, case, arch_id):
        r = make_rules(mesh, case, arch_id)
        r.mapping["embed"] = "data" if "data" in mesh.shape else None
        return r

    def rules_no_act_constraints(mesh, case, arch_id):
        r = make_rules(mesh, case, arch_id)
        for k in list(r.mapping):
            if k.startswith("act_"):
                r.mapping[k] = None
        return r

    def rules_seq_parallel_train(mesh, case, arch_id):
        r = make_rules(mesh, case, arch_id)
        r.mapping["seq"] = "model"
        return r

    if shape.startswith("decode") or shape.startswith("long"):
        V["kv-seq-sharded"] = {"rules": rules_seqshard}
        V["weights-fsdp"] = {"rules": rules_fsdp}
    else:
        V["no-act-constraints"] = {"rules": rules_no_act_constraints}
        V["seq-parallel"] = {"rules": rules_seq_parallel_train}

    return V


def run_variants(arch: str, shape: str, names: Optional[List[str]] = None,
                 extra: Optional[Dict[str, dict]] = None):
    from repro.core.policy import DitherPolicy
    from repro.launch import dryrun

    V = variants_for(arch, shape)
    if extra:
        V.update(extra)
    rows = []
    for name, spec in V.items():
        if names and name not in names and name != "baseline(paper)":
            continue
        # default: the paper-faithful policy; variants may override (or None)
        policy = spec["policy"] if "policy" in spec \
            else DitherPolicy(variant="paper", s=2.0)
        res = dryrun.run_cell(
            arch, shape,
            policy=policy,
            rules_override=spec.get("rules"),
            model_override=spec.get("model"),
            verbose=False)
        row = {"variant": name, "status": res.status,
               "compile_s": round(res.compile_s, 1)}
        if res.report:
            r = res.report
            row.update({
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "collective_s": r["collective_s"], "dominant": r["dominant"],
                "frac": r["roofline_fraction"],
                "useful": r["useful_ratio"],
                "by_op": r["collectives_by_op"],
            })
        else:
            row["reason"] = res.reason
        rows.append(row)
        print(json.dumps(row, default=str))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="", help="comma list (default all)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    names = [v for v in args.variants.split(",") if v] or None
    rows = run_variants(arch, shape, names)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()
