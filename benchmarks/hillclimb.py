"""§Perf hillclimb driver: run one (arch x shape) cell under a named list of
variants (sharding-rule mutations, dither policy, model config patches),
and print the before/after roofline terms + per-collective breakdown.

Each variant is a HYPOTHESIS about the dominant roofline term; the output
is the 'measure' step of the hypothesis -> change -> measure -> validate
loop recorded in EXPERIMENTS.md §Perf.

Run as a module *only from a fresh process* (it imports repro.launch.dryrun
which pins 512 host devices):

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen2.5-32b:train_4k

``bench()`` (the ``benchmarks.suite`` entry point) honors that constraint
by running the cell in a subprocess — the suite's own jax is already
initialized, so the 512-device pin could not take effect in-process — and
folds the per-variant roofline rows into the BENCH_hillclimb.json
SuiteRun like every other suite. Quick mode compiles the smoke config of
one small arch (``--smoke``) with two variants; rows are informational
trajectory (roofline terms of an AOT compile), not gated.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional


def variants_for(arch: str, shape: str) -> Dict[str, dict]:
    """Named variant registry. Keys map to EXPERIMENTS.md §Perf entries.

    Must stay import-side-effect free: bench() calls it in the suite-runner
    process just to derive placeholder row NAMES, so the dryrun import (an
    XLA_FLAGS 512-device mutation at module top) happens lazily inside the
    rules closures, which only ever run in the hillclimb subprocess."""
    from repro.core.policy import DitherPolicy

    V: Dict[str, dict] = {"baseline(paper)": {}}

    V["dither-off"] = {"policy": None}
    V["dither-int8-bwd"] = {"policy": DitherPolicy(variant="int8", s=2.0)}
    V["dither-row"] = {"policy": DitherPolicy(variant="row")}

    # sharding mutations
    def rules_seqshard(mesh, case, arch_id):
        from repro.launch.dryrun import make_rules

        r = make_rules(mesh, case, arch_id)
        r.mapping["cache_seq"] = "model"
        return r

    def rules_fsdp(mesh, case, arch_id):
        from repro.launch.dryrun import make_rules

        r = make_rules(mesh, case, arch_id)
        r.mapping["embed"] = "data" if "data" in mesh.shape else None
        return r

    def rules_no_act_constraints(mesh, case, arch_id):
        from repro.launch.dryrun import make_rules

        r = make_rules(mesh, case, arch_id)
        for k in list(r.mapping):
            if k.startswith("act_"):
                r.mapping[k] = None
        return r

    def rules_seq_parallel_train(mesh, case, arch_id):
        from repro.launch.dryrun import make_rules

        r = make_rules(mesh, case, arch_id)
        r.mapping["seq"] = "model"
        return r

    if shape.startswith("decode") or shape.startswith("long"):
        V["kv-seq-sharded"] = {"rules": rules_seqshard}
        V["weights-fsdp"] = {"rules": rules_fsdp}
    else:
        V["no-act-constraints"] = {"rules": rules_no_act_constraints}
        V["seq-parallel"] = {"rules": rules_seq_parallel_train}

    return V


def run_variants(arch: str, shape: str, names: Optional[List[str]] = None,
                 extra: Optional[Dict[str, dict]] = None,
                 smoke: bool = False):
    from repro.configs import get_smoke_model
    from repro.core.policy import DitherPolicy
    from repro.launch import dryrun

    V = variants_for(arch, shape)
    if extra:
        V.update(extra)
    rows = []
    for name, spec in V.items():
        if names and name not in names and name != "baseline(paper)":
            continue
        # default: the paper-faithful policy; variants may override (or None)
        policy = spec["policy"] if "policy" in spec \
            else DitherPolicy(variant="paper", s=2.0)
        model_override = spec.get("model")
        if smoke and model_override is None:
            # CI-sized cells: the arch's reduced config on the real mesh,
            # skipping the scan-anchor cost correction (compile-only probe)
            model_override = get_smoke_model(arch)
        res = dryrun.run_cell(
            arch, shape,
            policy=policy,
            rules_override=spec.get("rules"),
            model_override=model_override,
            correct_costs=not smoke,
            verbose=False)
        row = {"variant": name, "status": res.status,
               "compile_s": round(res.compile_s, 1)}
        if res.report:
            r = res.report
            row.update({
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "collective_s": r["collective_s"], "dominant": r["dominant"],
                "frac": r["roofline_fraction"],
                "useful": r["useful_ratio"],
                "by_op": r["collectives_by_op"],
            })
        else:
            row["reason"] = res.reason
        rows.append(row)
        print(json.dumps(row, default=str))
    return rows


QUICK_CELL = "gemma-2b:train_4k"
QUICK_VARIANTS = ("baseline(paper)", "dither-off")
FULL_CELL = "qwen2.5-32b:train_4k"
SUBPROCESS_TIMEOUT_S = 1800


def bench(quick: bool = True):
    """benchmarks.suite entry point: hillclimb rows as BenchResults.

    The cell runs in a fresh subprocess (dryrun must pin its 512 host
    devices before jax initializes). A failed or timed-out compile emits
    placeholder rows (status NOTRUN, the error in context) under the SAME
    per-variant names — the comparator's missing-bench policy would
    otherwise hard-fail ``--check`` on a CI host hiccup; since every
    hillclimb metric is ungated trajectory, placeholders pass the gate
    while keeping the failure visible in the artifact.
    """
    from repro.bench import BenchResult

    cell = QUICK_CELL if quick else FULL_CELL
    variants = QUICK_VARIANTS if quick else ()
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(here)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    rows, note = [], "ok"
    with tempfile.TemporaryDirectory() as td:
        out_path = os.path.join(td, "hillclimb.json")
        cmd = [sys.executable, "-m", "benchmarks.hillclimb", "--cell", cell,
               "--out", out_path]
        if quick:
            cmd += ["--smoke", "--variants", ",".join(QUICK_VARIANTS)]
        try:
            proc = subprocess.run(cmd, cwd=repo, env=env,
                                  capture_output=True, text=True,
                                  timeout=SUBPROCESS_TIMEOUT_S)
            if proc.returncode != 0:
                note = f"subprocess rc={proc.returncode}: " \
                       f"{proc.stderr.strip()[-400:]}"
            elif os.path.exists(out_path):
                with open(out_path) as f:
                    rows = json.load(f)
            else:
                note = "subprocess wrote no output"
        except subprocess.TimeoutExpired:
            note = f"subprocess timeout after {SUBPROCESS_TIMEOUT_S}s"

    if not rows:
        # placeholder rows keep the committed baseline's names present in
        # BOTH modes; variants_for is import-side-effect free (its dryrun
        # import is lazy inside the rules closures) so deriving names here
        # cannot mutate this process's XLA_FLAGS / device count
        expected = variants or tuple(variants_for(*cell.split(":")))
        rows = [{"variant": v, "status": "NOTRUN", "compile_s": 0.0,
                 "reason": note} for v in expected]

    results = [BenchResult(
        name="hillclimb/summary", value=0.0, unit="us",
        derived={"cells": float(len(rows))},
        context={"cell": cell, "mode": "smoke" if quick else "full",
                 "variants": ",".join(variants) or "all", "note": note})]
    for row in rows:
        derived = {}
        for k in ("compute_s", "memory_s", "collective_s", "frac", "useful"):
            if k in row and isinstance(row[k], (int, float)):
                derived[k] = float(row[k])
        derived["status_ok"] = 1.0 if row.get("status") == "OK" else 0.0
        results.append(BenchResult(
            name=f"hillclimb/{cell}/{row['variant']}",
            value=float(row.get("compile_s", 0.0)) * 1e6,
            unit="us",
            derived=derived,
            context={k: str(row[k]) for k in ("status", "dominant", "reason")
                     if k in row}))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="", help="comma list (default all)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config (CI-sized "
                    "compile probe; skips the scan-anchor cost correction)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    names = [v for v in args.variants.split(",") if v] or None
    rows = run_variants(arch, shape, names, smoke=args.smoke)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()
