"""Paper figs. 5/6/.10/.11 + the §distributed comm claim: as the number of
nodes N grows (and s is scaled with N), per-node sparsity rises and
worst-case bit-width falls while final accuracy stays flat — and, with the
``repro.comm`` wire format on the node->server hop, measured bytes-on-wire
shrink as sparsity grows, priced here against dense f32 exchange on the
TPU v5e interconnect.

``compare_topologies`` additionally races the flat compressed ring against
the two-level (intra-pod ring + inter-pod tree) reduce on the same
gradients: wire bytes per link class, pointwise error bounds, sequential
packs per segment, and modeled ICI/DCN seconds — written as JSON (see
``main``/``--json``) so the "when does the tree win" question has a
recorded answer per configuration.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.bench import BenchResult, Gate
from repro.comm import (ButterflyConfig, CommPolicy, HierConfig, RingConfig,
                        butterfly_allreduce_nsd, hier_allreduce_nsd,
                        reducer as make_reducer, ring_allreduce_nsd,
                        tree_rounds)
from repro.configs import paper_models as pm
from repro.core import DitherPolicy
from repro.obs import metrics as statslib
from repro.data import ClassifConfig, classification_batch
from repro.distributed import SSGDConfig, make_ssgd_step, shard_batch
from repro.launch.costmodel import (compression_speedup, price_overlap,
                                    price_reduce, price_wire_bytes)
from repro.utils.pytree import flatten_with_names
from repro.models.cnn import accuracy
from repro.optim import OptConfig, init_opt_state

RESULTS_JSON = os.path.join(os.path.dirname(__file__), "results",
                            "topology_compare.json")


def run(node_counts=(1, 2, 4, 8), steps: int = 40, batch: int = 32,
        seed: int = 0, comm: bool = True, topology: str = "ps",
        pods: int = 1) -> List[Dict]:
    rows = []
    for n in node_counts:
        statslib.reset()
        model = pm.mlp_mnist(hidden=(256, 256))
        key = jax.random.PRNGKey(seed)
        params, _ = model.init(key)
        opt_cfg = OptConfig(name="sgd", lr=0.05, momentum=0.9,
                            weight_decay=5e-4, grad_clip=None)
        dcfg = SSGDConfig(n_nodes=n, s_schedule="sqrt", s_base=2.0)
        pol = DitherPolicy(variant="paper", collect_stats=True,
                           stats_tag=f"dist{n}/")
        # comm-side NSD rides the same sqrt(N) schedule as the backprop
        # dither: more nodes -> sparser wire payloads too. The topology
        # kwarg routes the reduce through ring/hier instead of the ps
        # compress-then-average; the requested pod count is snapped to
        # gcd(pods, n) so every sweep point gets a valid (divisor) pod
        # grouping instead of crashing mid-sweep on an indivisible n.
        comm_policy = (CommPolicy(default="nsd", s=dcfg.s_for_n(),
                                  collect_stats=True,
                                  stats_tag=f"dist{n}/comm",
                                  topology=topology,
                                  pods=math.gcd(pods, n))
                       if comm else None)
        step_fn, used_policy = make_ssgd_step(model, opt_cfg, dcfg, pol,
                                              comm_policy=comm_policy)
        state = init_opt_state(params, opt_cfg)
        data_cfg = ClassifConfig(n_classes=10, img_size=28, channels=1,
                                 noise=0.5, seed=seed)
        t0 = time.perf_counter()
        for i in range(steps):
            b = classification_batch(data_cfg, i, batch=batch)
            params, state, _, _ = step_fn(params, state, shard_batch(b, n), key)
        us = (time.perf_counter() - t0) / steps * 1e6
        test = classification_batch(data_cfg, 10**6, batch=512)
        row = {
            "n_nodes": n,
            "s": used_policy.s,
            "acc": float(accuracy(params, model.cfg, test)) * 100,
            "sparsity": statslib.overall_sparsity() * 100,
            "max_bits": statslib.overall_max_bits(),
            "us_per_step": us,
        }
        if comm:
            cs = statslib.comm_summary().get(f"dist{n}/comm")
            if cs:
                row["wire_mb"] = cs["wire_bytes"] / 1e6
                row["wire_ratio"] = cs["ratio"]
                row["wire_s_v5e"] = price_wire_bytes(cs["wire_bytes"])
                row["comm_speedup"] = compression_speedup(
                    cs["wire_bytes"], cs["dense_bytes"])
        rows.append(row)
    return rows


def compare_topologies(n_nodes: int = 8, pods: int = 2,
                       shape=(256, 256), s: float = 2.0,
                       seed: int = 0) -> Dict:
    """Race flat ring vs hierarchical reduce on identical gradients.

    Returns a JSON-ready dict with, per topology: measured wire bytes
    (split by link class for the hierarchy), the reduce's pointwise error
    bound and the measured error vs the dense mean, sequential packs per
    segment, and the cost model's ICI/DCN seconds.
    """
    key = jax.random.PRNGKey(seed)
    grads = jnp.stack([
        jax.random.normal(jax.random.fold_in(key, i), shape) * 0.01
        for i in range(n_nodes)])
    dense_mean = jnp.mean(grads, axis=0)

    def row(name, mean, tele, priced, extra):
        return dict(
            topology=name, n_nodes=n_nodes,
            wire_bytes=float(tele.wire_bytes),
            dense_bytes=float(tele.dense_bytes),
            wire_ratio=float(tele.ratio),
            error_bound=float(tele.error_bound),
            max_err=float(jnp.max(jnp.abs(mean - dense_mean))),
            packs_per_segment=int(tele.packs_per_segment),
            **priced, **extra)

    mean_r, tele_r = ring_allreduce_nsd(grads, key, RingConfig(s=s))
    mean_h, tele_h = hier_allreduce_nsd(grads, key,
                                        HierConfig(pods=pods, s=s))
    mean_b, tele_b = butterfly_allreduce_nsd(grads, key,
                                             ButterflyConfig(pods=pods, s=s))
    rows = [
        row("ring", mean_r, tele_r,
            price_reduce(tele_r, nodes=n_nodes, pods=pods),
            {"pods": pods}),
        row("hier", mean_h, tele_h,
            price_reduce(tele_h, nodes=n_nodes, pods=pods),
            {"pods": pods, "per_pod": n_nodes // pods,
             "wire_ici_bytes": float(tele_h.wire_ici_bytes),
             "wire_dcn_bytes": float(tele_h.wire_dcn_bytes),
             "peak_dcn_bytes": float(tele_h.peak_dcn_bytes),
             "tree_rounds": tree_rounds(pods)}),
        row("butterfly", mean_b, tele_b,
            price_reduce(tele_b, nodes=n_nodes, pods=pods),
            {"pods": pods, "per_pod": n_nodes // pods,
             "wire_ici_bytes": float(tele_b.wire_ici_bytes),
             "wire_dcn_bytes": float(tele_b.wire_dcn_bytes),
             "peak_dcn_bytes": float(tele_b.peak_dcn_bytes)}),
    ]
    return {"n_nodes": n_nodes, "pods": pods, "shape": list(shape),
            "s": s, "seed": seed, "rows": rows}


def compare_butterfly(n_nodes: int = 8, pods: int = 4, shape=(128, 128),
                      s: float = 2.0, seed: int = 0) -> Dict:
    """Butterfly-vs-tree differential invariants, JSON-ready.

    Three exact claims ride zero-band gates downstream:

    * ``maxdiff_g1`` — with pods == 1 the butterfly collapses to the
      hierarchy's degenerate path bit-exactly (same packs, same keys).
    * ``packs_diff`` — at the requested pod count the sequential pack
      depth per segment matches the binomial tree exactly.
    * ``peak_excess`` — the recursive-halving exchange's busiest DCN
      line carries no more than the tree root's (the occupancy claim;
      holds from pods >= 4 where the log-G funnel dominates headers).
    """
    key = jax.random.PRNGKey(seed)
    grads = jnp.stack([
        jax.random.normal(jax.random.fold_in(key, i), shape) * 0.01
        for i in range(n_nodes)])

    m_h1, _ = hier_allreduce_nsd(grads, key, HierConfig(pods=1, s=s))
    m_b1, _ = butterfly_allreduce_nsd(grads, key, ButterflyConfig(pods=1, s=s))
    _, t_h = hier_allreduce_nsd(grads, key, HierConfig(pods=pods, s=s))
    m_b, t_b = butterfly_allreduce_nsd(grads, key,
                                       ButterflyConfig(pods=pods, s=s))
    dense_mean = jnp.mean(grads, axis=0)
    return {
        "n_nodes": n_nodes, "pods": pods, "shape": list(shape), "s": s,
        "maxdiff_g1": float(jnp.max(jnp.abs(m_b1 - m_h1))),
        "packs_diff": float(int(t_b.packs_per_segment)
                            - int(t_h.packs_per_segment)),
        "peak_excess": max(0.0, float(t_b.peak_dcn_bytes)
                           - float(t_h.peak_dcn_bytes)),
        "peak_ratio": (float(t_b.peak_dcn_bytes)
                       / max(float(t_h.peak_dcn_bytes), 1.0)),
        "error_bound": float(t_b.error_bound),
        "max_err": float(jnp.max(jnp.abs(m_b - dense_mean))),
    }


def compare_overlap(n_nodes: int = 4, pods: int = 2, hidden=(256, 256),
                    bucket_bytes: int = 256 * 1024, s: float = 2.0,
                    seed: int = 0, batch: int = 32) -> Dict:
    """Overlapped (bucketed) vs blocking reduce on real model gradients.

    Numerical claim: the bucketed reduce is BIT-EXACT equal to the
    blocking one (per-leaf keys depend on the leaf path, not the bucket),
    so ``maxdiff`` and ``wire_diff`` ride zero-band gates.

    Efficiency claim: overlap efficiency computed from the cost model
    (priced per-bucket comm seconds on a link calibrated to the measured
    aggregate bandwidth) must track the efficiency computed from measured
    per-bucket wall-clock — same :func:`price_overlap` recurrence over
    both, gated on the gap.
    """
    model = pm.mlp_mnist(hidden=hidden)
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)
    data_cfg = ClassifConfig(n_classes=10, img_size=28, channels=1,
                             noise=0.5, seed=seed)
    sb = shard_batch(classification_batch(data_cfg, 0, batch=batch), n_nodes)

    @jax.jit
    def node_grads(p, b):
        return jax.vmap(lambda nb: jax.grad(
            lambda q: model.loss(q, nb))(p))(b)

    grads = jax.block_until_ready(node_grads(params, sb))  # compile
    t0 = time.perf_counter()
    grads = jax.block_until_ready(node_grads(params, sb))
    bwd_s = time.perf_counter() - t0

    pol = CommPolicy(default="nsd", s=s, topology="hier", pods=pods)
    red_blk = make_reducer(pol, n_nodes=n_nodes, stacked=True)
    red_ovl = make_reducer(pol.replace(bucket_bytes=bucket_bytes),
                           n_nodes=n_nodes, stacked=True)
    k = jax.random.fold_in(key, 1)

    mean_blk, tele_blk, _ = red_blk.reduce(grads, k, 0)
    mean_ovl, tele_ovl, _ = red_ovl.reduce(grads, k, 0)
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(mean_blk), jax.tree.leaves(mean_ovl))]

    # per-bucket measured wall-clock + modeled wire seconds, same schedule
    plan = red_ovl.plan_for(grads)
    by_name = dict(flatten_with_names(grads))
    bucket_s, bucket_wire = [], []
    for names in plan.buckets:
        sub = {n: by_name[n] for n in names}
        step_fn = jax.jit(lambda g, kk: red_blk.reduce(g, kk, 0)[:2])
        jax.block_until_ready(step_fn(sub, k))  # compile
        t0 = time.perf_counter()
        _, tele = step_fn(sub, k)
        jax.block_until_ready(tele.wire_bytes)
        bucket_s.append(time.perf_counter() - t0)
        bucket_wire.append(float(tele.wire_bytes))
    # calibrate the modeled link to the measured aggregate bandwidth so
    # the gate compares SCHEDULES, not CPU-sim throughput vs v5e specs
    bw = sum(bucket_wire) / max(sum(bucket_s), 1e-12)
    modeled_s = [w / bw for w in bucket_wire]
    measured = price_overlap(plan.bucket_bytes, bucket_s, bwd_s=bwd_s)
    modeled = price_overlap(plan.bucket_bytes, modeled_s, bwd_s=bwd_s)
    eff_meas = measured["overlap_efficiency"]
    eff_model = modeled["overlap_efficiency"]
    statslib.emit_overlap("bench/overlap", 0, plan.n_buckets,
                          measured["hidden_s"], measured["exposed_s"],
                          eff_meas)
    return {
        "n_nodes": n_nodes, "pods": pods, "bucket_bytes": bucket_bytes,
        "n_buckets": plan.n_buckets,
        "maxdiff": max(diffs),
        "wire_diff": abs(float(tele_blk.wire_bytes)
                         - float(tele_ovl.wire_bytes)),
        "bwd_s": bwd_s,
        "eff_measured": eff_meas,
        "eff_modeled": eff_model,
        "eff_gap": abs(eff_model - eff_meas),
    }


def write_topology_json(result: Dict, path: str = RESULTS_JSON) -> str:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def bench(quick: bool = True) -> List[BenchResult]:
    """Scaling sweep + topology race.

    Sweep rows gate accuracy/sparsity (training claims) and the wire
    ratio (compression claim; high = regression). Topology rows gate the
    deterministic reduce invariants tightly: packs per segment is exact,
    wire bytes and the analytic error bound move only if the algorithm
    changes.
    """
    rows = run(node_counts=(1, 2, 4) if quick else (1, 2, 4, 8, 16),
               steps=30 if quick else 80)
    out = []
    for r in rows:
        derived = {"s": r["s"], "acc": r["acc"], "sparsity": r["sparsity"],
                   "max_bits": r["max_bits"]}
        gates = {"acc": Gate(abs=10.0, direction="low"),
                 "sparsity": Gate(abs=8.0, direction="low"),
                 "max_bits": Gate(abs=1.0, direction="high")}
        if "wire_ratio" in r:
            derived.update(wire_mb=r["wire_mb"], wire_ratio=r["wire_ratio"],
                           comm_speedup=r["comm_speedup"])
            gates["wire_ratio"] = Gate(rel=0.15, direction="high")
        out.append(BenchResult(
            name=f"fig5-6/N={r['n_nodes']}", value=r["us_per_step"],
            unit="us/step", derived=derived, gates=gates))
    # topology race: flat ring vs two-level reduce, recorded as JSON
    t0 = time.perf_counter()
    cmp = compare_topologies(n_nodes=8, pods=2,
                             shape=(128, 128) if quick else (256, 256))
    us = (time.perf_counter() - t0) * 1e6
    write_topology_json(cmp)
    for r in cmp["rows"]:
        out.append(BenchResult(
            name=f"topology/{r['topology']}/N={r['n_nodes']}", value=us,
            unit="us",
            derived={"packs_per_segment": float(r["packs_per_segment"]),
                     "error_bound": r["error_bound"],
                     "max_err": r["max_err"],
                     "wire_kb": r["wire_bytes"] / 1e3,
                     "ici_us": r["ici_s"] * 1e6,
                     "dcn_us": r["dcn_s"] * 1e6,
                     "total_us": r["total_s"] * 1e6},
            gates={"packs_per_segment": Gate(abs=0.0, direction="both"),
                   "error_bound": Gate(rel=0.05, direction="high"),
                   "wire_kb": Gate(rel=0.05, direction="high")},
            context={"pods": cmp["pods"], "shape": "x".join(
                str(d) for d in cmp["shape"])}))
    # butterfly DCN invariants: G=1 bit-exact vs tree, equal pack depth,
    # peak-line occupancy no worse than the tree root at pods=4
    t0 = time.perf_counter()
    bf = compare_butterfly(n_nodes=8, pods=4,
                           shape=(64, 64) if quick else (128, 128))
    us = (time.perf_counter() - t0) * 1e6
    out.append(BenchResult(
        name="butterfly/vs-tree/N=8", value=us, unit="us",
        derived={"maxdiff_g1": bf["maxdiff_g1"],
                 "packs_diff": bf["packs_diff"],
                 "peak_excess": bf["peak_excess"],
                 "peak_ratio": bf["peak_ratio"],
                 "error_bound": bf["error_bound"]},
        gates={"maxdiff_g1": Gate(abs=0.0, direction="both"),
               "packs_diff": Gate(abs=0.0, direction="both"),
               "peak_excess": Gate(abs=0.0, direction="high"),
               "error_bound": Gate(rel=0.05, direction="high")},
        context={"pods": bf["pods"], "shape": "x".join(
            str(d) for d in bf["shape"])}))
    # overlap scheduling: bucketed reduce bit-exact vs blocking, and the
    # cost model's overlap efficiency tracks the measured schedule
    t0 = time.perf_counter()
    ov = compare_overlap(hidden=(128, 128) if quick else (256, 256))
    us = (time.perf_counter() - t0) * 1e6
    out.append(BenchResult(
        name="overlap/hier-bucketed/N=4", value=us, unit="us",
        derived={"maxdiff": ov["maxdiff"],
                 "wire_diff": ov["wire_diff"],
                 "n_buckets": float(ov["n_buckets"]),
                 "eff_measured": ov["eff_measured"],
                 "eff_modeled": ov["eff_modeled"],
                 "eff_gap": ov["eff_gap"]},
        gates={"maxdiff": Gate(abs=0.0, direction="both"),
               "wire_diff": Gate(abs=0.0, direction="both"),
               "n_buckets": Gate(abs=0.0, direction="both"),
               # wall-clock noise moves the measured efficiency; the gate
               # bounds the model-vs-measurement gap, not the raw number
               "eff_gap": Gate(abs=0.35, direction="high")},
        context={"bucket_bytes": ov["bucket_bytes"]}))
    return out


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--s", type=float, default=2.0)
    ap.add_argument("--json", default=RESULTS_JSON,
                    help="where to write the topology comparison JSON")
    args = ap.parse_args(argv)
    result = compare_topologies(n_nodes=args.nodes, pods=args.pods,
                                s=args.s)
    path = write_topology_json(result, args.json)
    print(json.dumps(result, indent=1))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
