"""Paper figs. 5/6/.10/.11 + the §distributed comm claim: as the number of
nodes N grows (and s is scaled with N), per-node sparsity rises and
worst-case bit-width falls while final accuracy stays flat — and, with the
``repro.comm`` wire format on the node->server hop, measured bytes-on-wire
shrink as sparsity grows, priced here against dense f32 exchange on the
TPU v5e interconnect."""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from repro.comm import CommPolicy
from repro.configs import paper_models as pm
from repro.core import DitherPolicy
from repro.core import stats as statslib
from repro.data import ClassifConfig, classification_batch
from repro.distributed import SSGDConfig, make_ssgd_step, shard_batch
from repro.launch.costmodel import compression_speedup, price_wire_bytes
from repro.models.cnn import accuracy
from repro.optim import OptConfig, init_opt_state


def run(node_counts=(1, 2, 4, 8), steps: int = 40, batch: int = 32,
        seed: int = 0, comm: bool = True) -> List[Dict]:
    rows = []
    for n in node_counts:
        statslib.reset()
        model = pm.mlp_mnist(hidden=(256, 256))
        key = jax.random.PRNGKey(seed)
        params, _ = model.init(key)
        opt_cfg = OptConfig(name="sgd", lr=0.05, momentum=0.9,
                            weight_decay=5e-4, grad_clip=None)
        dcfg = SSGDConfig(n_nodes=n, s_schedule="sqrt", s_base=2.0)
        pol = DitherPolicy(variant="paper", collect_stats=True,
                           stats_tag=f"dist{n}/")
        # comm-side NSD rides the same sqrt(N) schedule as the backprop
        # dither: more nodes -> sparser wire payloads too
        comm_policy = (CommPolicy(default="nsd", s=dcfg.s_for_n(),
                                  collect_stats=True,
                                  stats_tag=f"dist{n}/comm")
                       if comm else None)
        step_fn, used_policy = make_ssgd_step(model, opt_cfg, dcfg, pol,
                                              comm_policy=comm_policy)
        state = init_opt_state(params, opt_cfg)
        data_cfg = ClassifConfig(n_classes=10, img_size=28, channels=1,
                                 noise=0.5, seed=seed)
        t0 = time.perf_counter()
        for i in range(steps):
            b = classification_batch(data_cfg, i, batch=batch)
            params, state, _ = step_fn(params, state, shard_batch(b, n), key)
        us = (time.perf_counter() - t0) / steps * 1e6
        test = classification_batch(data_cfg, 10**6, batch=512)
        row = {
            "n_nodes": n,
            "s": used_policy.s,
            "acc": float(accuracy(params, model.cfg, test)) * 100,
            "sparsity": statslib.overall_sparsity() * 100,
            "max_bits": statslib.overall_max_bits(),
            "us_per_step": us,
        }
        if comm:
            cs = statslib.comm_summary().get(f"dist{n}/comm")
            if cs:
                row["wire_mb"] = cs["wire_bytes"] / 1e6
                row["wire_ratio"] = cs["ratio"]
                row["wire_s_v5e"] = price_wire_bytes(cs["wire_bytes"])
                row["comm_speedup"] = compression_speedup(
                    cs["wire_bytes"], cs["dense_bytes"])
        rows.append(row)
    return rows


def bench(quick: bool = True):
    rows = run(node_counts=(1, 2, 4) if quick else (1, 2, 4, 8, 16),
               steps=30 if quick else 80)
    out = []
    for r in rows:
        derived = (f"s={r['s']:.2f} acc={r['acc']:.1f}%"
                   f" sparsity={r['sparsity']:.1f}%"
                   f" bits={r['max_bits']:.0f}")
        if "wire_ratio" in r:
            derived += (f" wire={r['wire_ratio'] * 100:.1f}%dense"
                        f" ({r['comm_speedup']:.1f}x link speedup)")
        out.append((f"fig5-6/N={r['n_nodes']}", r["us_per_step"], derived))
    return out
