"""Kernel micro-benchmarks.

Wall-clock here is interpret-mode (CPU) and NOT indicative of TPU perf; the
meaningful derived metric is the *work-skipped fraction* (tiles masked off)
and the dense-vs-kernel FLOP ratio, which transfer to hardware. The numbers
feed EXPERIMENTS.md §Perf alongside the dry-run roofline terms.

The bitmap pack/unpack pair is additionally timed in BOTH interpret and
compiled mode. On CPU the compiled path is structurally unavailable
(``compiled=0`` in the row context); on a TPU host the same suite records
the compiled/interpret gap, so the lowering win of the sublane-rotate
layout shows up in the committed perf trajectory the day the suite runs on
hardware.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.bench import BenchResult, Gate
from repro.quant.wire import tile_mask_from_bitmap
from repro.core.rowdither import row_dither_compact
from repro.kernels.bsp_matmul.bsp_matmul import bsp_matmul, bsp_matmul_int8
from repro.kernels.bsp_matmul.ref import (bsp_matmul_blocked_ref,
                                          bsp_matmul_int8_ref)
from repro.kernels.ops import dithered_backward_matmuls, nsd_quantize_kernel
from repro.kernels.pack.pack import bitmap_pack_blocked, bitmap_unpack_blocked


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _bench_pack(k8: jax.Array) -> List[BenchResult]:
    """Pack/unpack rows: interpret timing gated metrics + compiled gap."""
    out = []
    bitmap, nnz = bitmap_pack_blocked(k8, interpret=True)
    sparsity = 1.0 - float(jnp.sum(nnz)) / k8.size
    # bitmap wire cost vs the dense f32 tensor it indexes: 1/32 by layout
    ratio = bitmap.size / (k8.size * 4)
    for name, fn, args in (
            ("pack_bitmap", bitmap_pack_blocked, (k8,)),
            ("unpack_bitmap", bitmap_unpack_blocked, (bitmap,))):
        us_interp = _time(lambda f=fn, a=args: f(*a, interpret=True))
        derived = {"elem_sparsity": sparsity, "bitmap_dense_ratio": ratio}
        context = {"compiled": 0,
                   "shape": "x".join(str(d) for d in k8.shape)}
        try:
            us_comp = _time(lambda f=fn, a=args: f(*a, interpret=False))
            context["compiled"] = 1
            # only present when the compiled path exists: a NaN here would
            # make the BENCH json invalid for strict parsers
            derived["compiled_speedup"] = us_interp / max(us_comp, 1e-9)
        except Exception as e:
            # record WHY: on CPU this is the expected no-compiled-pallas
            # error, but on a TPU host it would be a Mosaic lowering
            # failure — exactly the signal this row exists to surface
            context["compile_error"] = repr(e)[:160]
        out.append(BenchResult(
            name=f"kern/{name}", value=us_interp, unit="us(interpret)",
            derived=derived,
            gates={"bitmap_dense_ratio": Gate(abs=0.0, direction="both")},
            context=context))
    return out


def _density_operands(key, M, K, N, density, block=128):
    """Deterministic operands with EXACTLY round(density * n_tiles) occupied
    tiles, evenly spread over the tile grid; the consumed mask is derived
    from the packed wire bitmap (never a dense recompute), and the bench
    asserts it matches the intended tile layout."""
    import numpy as np

    mt, kt = M // block, K // block
    n_tiles = mt * kt
    n_occ = int(round(density * n_tiles))
    intended = np.zeros((mt, kt), np.int32)
    if n_occ:
        idx = np.round(np.linspace(0, n_tiles - 1, n_occ)).astype(np.int64)
        intended.reshape(-1)[np.unique(idx)] = 1
    r = jax.random.randint(key, (M, K), -127, 128, jnp.int32)
    # guarantee every occupied tile is non-zero everywhere (no accidental
    # zeros flipping bitmap bits): map to [1, 127] with the sign of r
    nz = jnp.where(r >= 0, r % 127 + 1, -((-r) % 127 + 1)).astype(jnp.int8)
    elem = jnp.repeat(jnp.repeat(jnp.asarray(intended) != 0, block, 0),
                      block, 1)
    k_q = jnp.where(elem, nz, jnp.int8(0))
    bitmap, _ = bitmap_pack_blocked(k_q, bm=block, bn=block, interpret=True)
    mask = tile_mask_from_bitmap(bitmap, block, block)
    assert (jnp.asarray(intended) == mask).all(), "bitmap mask != intended"
    b = (jax.random.normal(jax.random.fold_in(key, 9), (K, N), jnp.float32)
         * 0.1)
    b_q = jax.random.randint(jax.random.fold_in(key, 10), (K, N), -127, 128,
                             jnp.int32).astype(jnp.int8)
    return k_q, mask, b, b_q


def _bench_density_curve(quick: bool) -> List[BenchResult]:
    """Speedup-vs-density curve for the tile-skipping matmul kernels.

    Per density: interpret-mode bit-exactness invariants (zero-banded
    gates — int8 kernel vs the int8 oracle, f32 kernel vs the
    accumulation-order-exact blocked oracle) + the tile-skip ratio the
    mask actually delivers. Timing (interpret-mode, CPU) is recorded for
    the trajectory, never gated; the crossover row derives the largest
    density at which the masked kernel still beats the dense dequantized
    matmul in wall-clock.
    """
    M = K = N = 512 if quick else 1024
    key = jax.random.PRNGKey(42)
    delta = jnp.float32(0.01)
    scale = jnp.float32(0.01 * 0.02)
    out = []
    dense_us = None
    curve = []
    for density in (0.0, 0.125, 0.25, 0.5, 0.75, 1.0):
        k_q, mask, b, b_q = _density_operands(key, M, K, N, density)
        o_i8 = bsp_matmul_int8(k_q, b_q, scale, mask, interpret=True)
        r_i8 = bsp_matmul_int8_ref(k_q, b_q, scale, mask)
        o_f32 = bsp_matmul(k_q, delta, b, mask, interpret=True)
        r_f32 = bsp_matmul_blocked_ref(k_q, delta, b, mask)
        err_i8 = float(jnp.max(jnp.abs(o_i8 - r_i8)))
        err_f32 = float(jnp.max(jnp.abs(o_f32 - r_f32)))
        tile_skip = 1.0 - float(jnp.mean(mask != 0))
        us = _time(lambda kq=k_q, bq=b_q, m=mask: bsp_matmul_int8(
            kq, bq, scale, m, interpret=True))
        if dense_us is None:
            dense_fn = jax.jit(lambda kq, bb: (kq.astype(jnp.float32)
                                               * delta) @ bb)
            dense_us = _time(lambda kq=k_q, bb=b: dense_fn(kq, bb))
        curve.append((density, us))
        out.append(BenchResult(
            name=f"kern/bsp_density_{density:g}", value=us,
            unit="us(interpret)",
            derived={"tile_skip": tile_skip,
                     "int8_max_abs_err": err_i8,
                     "f32_max_abs_err": err_f32,
                     "speedup_vs_dense": dense_us / max(us, 1e-9)},
            gates={"tile_skip": Gate(abs=0.0, direction="both"),
                   "int8_max_abs_err": Gate(abs=0.0, direction="both"),
                   "f32_max_abs_err": Gate(abs=0.0, direction="both")},
            context={"shape": f"({M},{K},{N})"}))
    under = [d for d, us in curve if us <= dense_us]
    crossover = max(under) if under else 0.0
    out.append(BenchResult(
        name="kern/bsp_crossover", value=dense_us, unit="us(dense-ref)",
        derived={"crossover_density": crossover},
        context={"note": "largest density where masked kernel beats the "
                         "dense dequantized matmul (interpret mode; "
                         "timing-derived, not gated)"}))
    return out


def bench(quick: bool = True) -> List[BenchResult]:
    key = jax.random.PRNGKey(0)
    out = []
    T, K, N = (512, 512, 512) if quick else (2048, 1024, 2048)
    g = jax.random.normal(key, (T, N), jnp.float32) * 0.01
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
    w = jax.random.normal(jax.random.fold_in(key, 2), (K, N)) * 0.02

    for s in (2.0, 8.0):
        us = _time(lambda: nsd_quantize_kernel(g, key, s, bm=128, bn=128))
        k_q, delta, nnz = nsd_quantize_kernel(g, key, s, bm=128, bn=128)
        sp = float(jnp.mean(k_q == 0))
        tiles_skipped = float(jnp.mean(nnz == 0))
        out.append(BenchResult(
            name=f"kern/nsd_quant_s{s:g}", value=us, unit="us",
            derived={"elem_sparsity": sp, "tile_skip": tiles_skipped},
            gates={"elem_sparsity": Gate(abs=0.05, direction="low")}))

    us = _time(lambda: dithered_backward_matmuls(
        g, x, w, key, 2.0, int8_operands=True))
    out.append(BenchResult(
        name="kern/dithered_bwd_int8", value=us, unit="us",
        context={"shape": f"({T},{K},{N})",
                 "note": "both products int8-MXU path"}))

    # structured row dither: fraction of rows (=MXU work) removed
    for alpha in (1.0, 2.0):
        c = row_dither_compact(g, key, alpha, capacity=T)
        kept = float(c.n_rows) / T
        us = _time(lambda: row_dither_compact(g, key, alpha, capacity=T))
        out.append(BenchResult(
            name=f"kern/row_dither_a{alpha:g}", value=us, unit="us",
            derived={"rows_kept": kept},
            gates={"rows_kept": Gate(abs=0.15, direction="both")}))

    # wire-format bitmap pack/unpack on the s=8 operating point
    k8 = nsd_quantize_kernel(g, key, 8.0, bm=128, bn=128)[0]
    out.extend(_bench_pack(k8))

    # speedup-vs-density curve with bit-exact zero-band invariants
    out.extend(_bench_density_curve(quick))
    return out
