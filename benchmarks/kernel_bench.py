"""Kernel micro-benchmarks.

Wall-clock here is interpret-mode (CPU) and NOT indicative of TPU perf; the
meaningful derived metric is the *work-skipped fraction* (tiles masked off)
and the dense-vs-kernel FLOP ratio, which transfer to hardware. The numbers
feed EXPERIMENTS.md §Perf alongside the dry-run roofline terms.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import nsd
from repro.core.rowdither import row_dither_compact
from repro.kernels.ops import dithered_backward_matmuls, nsd_quantize_kernel


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench(quick: bool = True) -> List[Tuple[str, float, str]]:
    key = jax.random.PRNGKey(0)
    out = []
    T, K, N = (512, 512, 512) if quick else (2048, 1024, 2048)
    g = jax.random.normal(key, (T, N), jnp.float32) * 0.01
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
    w = jax.random.normal(jax.random.fold_in(key, 2), (K, N)) * 0.02

    for s in (2.0, 8.0):
        us = _time(lambda: nsd_quantize_kernel(g, key, s, bm=128, bn=128))
        k_q, delta, nnz = nsd_quantize_kernel(g, key, s, bm=128, bn=128)
        sp = float(jnp.mean(k_q == 0))
        tiles_skipped = float(jnp.mean(nnz == 0))
        out.append((f"kern/nsd_quant_s{s:g}", us,
                    f"elem_sparsity={sp:.3f} tile_skip={tiles_skipped:.3f}"))

    us = _time(lambda: dithered_backward_matmuls(
        g, x, w, key, 2.0, int8_operands=True))
    out.append(("kern/dithered_bwd_int8", us,
                f"shape=({T},{K},{N}) both products int8-MXU path"))

    # structured row dither: fraction of rows (=MXU work) removed
    for alpha in (1.0, 2.0):
        c = row_dither_compact(g, key, alpha, capacity=T)
        kept = float(c.n_rows) / T
        us = _time(lambda: row_dither_compact(g, key, alpha, capacity=T))
        out.append((f"kern/row_dither_a{alpha:g}", us,
                    f"rows_kept={kept:.3f} contraction_flops_x{kept:.3f}"))
    return out
