"""Roofline table reader: renders the dry-run grid JSON (produced by
``python -m repro.launch.dryrun --all --both-meshes --out <json>``) as the
EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "results", "dryrun_grid.json")
OPTIMIZED_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "results", "dryrun_grid_optimized.json")


def load(path: str = DEFAULT_PATH):
    with open(path) as f:
        return json.load(f)


def render(cells, mesh: str = "16x16") -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>10s} {'useful':>7s} {'frac':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "SKIPPED":
            lines.append(f"{c['arch']:22s} {c['shape']:12s} "
                         f"SKIPPED: {c['reason']}")
            continue
        if c["status"] == "FAILED":
            lines.append(f"{c['arch']:22s} {c['shape']:12s} "
                         f"FAILED: {c['reason'][:60]}")
            continue
        r = c["report"]
        lines.append(
            f"{c['arch']:22s} {c['shape']:12s} {r['compute_s']:9.4f} "
            f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{r['roofline_fraction']:7.4f}")
    return "\n".join(lines)


def bench(quick: bool = True) -> List[Tuple[str, float, str]]:
    out = []
    for tag, path in (("baseline", DEFAULT_PATH),
                      ("optimized", OPTIMIZED_PATH)):
        if not os.path.exists(path):
            out.append((f"roofline/{tag}", 0.0,
                        "grid not found - run repro.launch.dryrun --all"))
            continue
        for c in load(path):
            # multi-pod cells skip the scan-cost anchor correction (they
            # exist to prove the pod axis lowers), so only single-pod rows
            # carry valid roofline terms
            if c["status"] != "OK" or c["mesh"] != "16x16":
                continue
            r = c["report"]
            out.append((
                f"roofline-{tag}/{c['arch']}/{c['shape']}",
                max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
                f"dom={r['dominant']} frac={r['roofline_fraction']:.4f} "
                f"useful={r['useful_ratio']:.3f}"))
    return out
