"""Roofline table reader: renders the dry-run grid JSON (produced by
``python -m repro.launch.dryrun --all --both-meshes --out <json>``) as the
EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import json
import os
from typing import List

from repro.bench import BenchResult

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "results", "dryrun_grid.json")
OPTIMIZED_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "results", "dryrun_grid_optimized.json")


def load(path: str = DEFAULT_PATH):
    with open(path) as f:
        return json.load(f)


def render(cells, mesh: str = "16x16") -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>10s} {'useful':>7s} {'frac':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "SKIPPED":
            lines.append(f"{c['arch']:22s} {c['shape']:12s} "
                         f"SKIPPED: {c['reason']}")
            continue
        if c["status"] == "FAILED":
            lines.append(f"{c['arch']:22s} {c['shape']:12s} "
                         f"FAILED: {c['reason'][:60]}")
            continue
        r = c["report"]
        lines.append(
            f"{c['arch']:22s} {c['shape']:12s} {r['compute_s']:9.4f} "
            f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{r['roofline_fraction']:7.4f}")
    return "\n".join(lines)


def bench(quick: bool = True) -> List[BenchResult]:
    """Ungated reader rows: the dry-run grid is an offline artifact, so
    its absence is recorded (not failed) and its terms are informational
    trajectory, not a CI gate.

    The per-tag summary row is ALWAYS emitted under the same stable name
    whether or not the grid file exists — the committed baseline holds
    these names, and generating the grid later must surface the per-cell
    rows as ``new`` (passing), never flip the summary to ``missing``
    (failing)."""
    out = []
    for tag, path in (("baseline", DEFAULT_PATH),
                      ("optimized", OPTIMIZED_PATH)):
        cells = []
        if os.path.exists(path):
            for c in load(path):
                # multi-pod cells skip the scan-cost anchor correction
                # (they exist to prove the pod axis lowers), so only
                # single-pod rows carry valid roofline terms
                if c["status"] != "OK" or c["mesh"] != "16x16":
                    continue
                r = c["report"]
                cells.append(BenchResult(
                    name=f"roofline-{tag}/{c['arch']}/{c['shape']}",
                    value=max(r["compute_s"], r["memory_s"],
                              r["collective_s"]) * 1e6,
                    unit="us",
                    derived={"roofline_fraction": r["roofline_fraction"],
                             "useful_ratio": r["useful_ratio"]},
                    context={"dominant": r["dominant"]}))
            note = "grid loaded"
        else:
            note = "grid not found - run repro.launch.dryrun --all"
        out.append(BenchResult(
            name=f"roofline/{tag}", value=0.0, unit="us",
            derived={"cells": float(len(cells))},
            context={"note": note}))
        out.extend(cells)
    return out
