"""Quant-engine suite: the `repro.quant` registry acceptance gates.

Races every registered codec through the one front door and gates the
claims that make the registry trustworthy:

* **registry_race** — for EVERY codec in ``codec_names()``: encode ->
  decode round-trips within the codec's own ``error_bound`` (zero-width
  band on the worst bound violation across codecs); ``nsd`` decode is
  BIT-EXACT against the ``nsd_fakequant`` reference (zero-width band);
  fp32/remat are identity. Per-codec compression ratios
  (dense/stored_nbytes) are banded so a layout change cannot silently
  fatten a format.
* **compute_on_packed** — the nsd packed-domain backward products (jnp
  backend) match decode-then-matmul within float tolerance; recorded with
  a tight band.
* **encode_timing** — per-codec encode+decode wall clock, recorded
  UNGATED (shared-runner wall clock is noise).
* **grad_codec_int4** — training with the registry codec ``int4@g32``
  swapped onto the cotangent (``DitherPolicy.grad_codec``) converges
  within the committed band of the paper NSD arm.
* **moments** — adamw with ``mu_codec=m8`` / ``nu_codec=u8`` (8-bit
  stored moments through the registry) lands within the committed
  accuracy band of fp32-moment adamw on the same harness; sgd momentum
  with ``m8`` alongside.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy
from repro.quant import (codec_names, decode, dense_nbytes, encode,
                         error_bound, get_codec, nsd_fakequant, parse_spec,
                         resid_key, stored_nbytes)

from benchmarks.harness import train_classifier

# every registered codec raced as SPEC strings (parameterized forms
# exercise the grammar, not just the bare names)
RACE_SPECS = ("fp32", "remat", "bf16", "int8", "nsd", "nsd@0.5",
              "int8_absmax", "int4@g32", "int4@g64", "m8", "u8")


def _test_tensor(spec: str, key) -> jax.Array:
    x = jax.random.normal(key, (64, 256), jnp.float32) * 3.0
    if parse_spec(spec).codec == "u8":
        return jnp.square(x)  # second moments are non-negative
    return x


def registry_race(seed: int = 0) -> Dict[str, float]:
    """Round-trip every codec; worst bound violation + compression."""
    raced = set()
    out: Dict[str, float] = {"worst_err_over_bound": 0.0}
    for i, spec in enumerate(RACE_SPECS):
        raced.add(parse_spec(spec).codec)
        key = resid_key(jax.random.fold_in(jax.random.PRNGKey(seed), i))
        x = _test_tensor(spec, key)
        enc = encode(spec, x, key)
        dec = decode(spec, enc)
        label = spec.replace("@", "_")
        out[f"{label}_compression_x"] = (
            dense_nbytes(x.shape, x.dtype)
            / stored_nbytes(spec, x.shape, x.dtype))
        if parse_spec(spec).codec in ("fp32", "remat"):
            out[f"{label}_max_abs_diff"] = float(jnp.max(jnp.abs(dec - x)))
            continue
        if parse_spec(spec).codec == "nsd":
            ref = nsd_fakequant(x, key, parse_spec(spec).param)
            out[f"{label}_max_abs_diff"] = float(jnp.max(jnp.abs(dec - ref)))
        bound = error_bound(spec, enc)
        over = float(jnp.max(jnp.abs(dec - x) / (bound + 1e-12)))
        out[f"{label}_err_over_bound"] = over
        out["worst_err_over_bound"] = max(out["worst_err_over_bound"], over)
    missing = set(codec_names()) - raced
    if missing:  # a newly registered codec MUST join the race
        raise AssertionError(f"codecs registered but not raced: {missing}")
    return out


def packed_compute_metrics(seed: int = 0) -> Dict[str, float]:
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (32, 256), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, 128), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 2), (128, 256), jnp.float32)
    enc = encode("nsd", g, key)
    codec, spec = get_codec("nsd"), parse_spec("nsd")
    dx, dw = codec.compute_on_packed(spec, enc, x, w, backend="jnp")
    g_hat = decode("nsd", enc)
    dx_ref, dw_ref = g_hat @ w.T, x.T @ g_hat
    def rel(a, b):
        return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-12))

    return {"dx_rel_err": rel(dx, dx_ref), "dw_rel_err": rel(dw, dw_ref)}


def timing_metrics(seed: int = 0, reps: int = 20) -> Dict[str, float]:
    key = jax.random.PRNGKey(seed)
    out: Dict[str, float] = {}
    for spec in RACE_SPECS:
        x = _test_tensor(spec, key)
        enc_fn = jax.jit(lambda v, s=spec: encode(s, v, resid_key(key)))
        dec_fn = jax.jit(lambda e, s=spec: decode(s, e))
        enc = jax.block_until_ready(enc_fn(x))  # compile outside the clock
        jax.block_until_ready(dec_fn(enc))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(dec_fn(enc_fn(x)))
        out[f"{spec.replace('@', '_')}_roundtrip_us"] = (
            (time.perf_counter() - t0) / reps * 1e6)
    return out


def run(quick: bool = True) -> Dict[str, Dict]:
    steps = 40 if quick else 120
    model = pm.lenet300100()
    arms: Dict[str, Dict[str, float]] = {}
    arms["paper"] = train_classifier(
        model, DitherPolicy(variant="paper", s=2.0), steps=steps)
    arms["grad_int4"] = train_classifier(
        model, DitherPolicy(variant="paper", s=2.0, grad_codec="int4@g32"),
        steps=steps)
    arms["sgd_m8"] = train_classifier(
        model, DitherPolicy(variant="paper", s=2.0), steps=steps,
        opt_overrides={"mu_codec": "m8"})
    adamw = {"name": "adamw", "lr": 3e-3}
    arms["adamw_fp32"] = train_classifier(
        model, DitherPolicy(variant="paper", s=2.0), steps=steps,
        opt_overrides=adamw)
    arms["adamw_m8u8"] = train_classifier(
        model, DitherPolicy(variant="paper", s=2.0), steps=steps,
        opt_overrides=dict(adamw, mu_codec="m8", nu_codec="u8"))
    return {"arms": arms, "race": registry_race(),
            "packed": packed_compute_metrics(), "timing": timing_metrics()}


def bench(quick: bool = True) -> List[BenchResult]:
    out = run(quick=quick)
    arms, race = out["arms"], out["race"]
    results = [
        BenchResult(
            name="quant_bench/registry_race",
            value=race["worst_err_over_bound"], unit="x",
            derived=dict(race),
            gates={
                # nsd through the registry == the fakequant reference,
                # bit for bit; identity codecs exact — zero-width bands
                "nsd_max_abs_diff": Gate(abs=0.0, direction="both"),
                "nsd_0.5_max_abs_diff": Gate(abs=0.0, direction="both"),
                "fp32_max_abs_diff": Gate(abs=0.0, direction="both"),
                "remat_max_abs_diff": Gate(abs=0.0, direction="both"),
                # every codec honors its own characterized bound (<= 1,
                # small fp headroom)
                "worst_err_over_bound": Gate(abs=0.05, direction="high"),
                # layout accounting: a format change that fattens storage
                # must show up here
                "int8_compression_x": Gate(rel=0.02, direction="low"),
                "int4_g32_compression_x": Gate(rel=0.02, direction="low"),
                "bf16_compression_x": Gate(abs=0.0, direction="both"),
                "m8_compression_x": Gate(rel=0.02, direction="low"),
                "u8_compression_x": Gate(rel=0.02, direction="low"),
            },
        ),
        BenchResult(
            name="quant_bench/compute_on_packed",
            value=out["packed"]["dw_rel_err"], unit="x",
            derived=dict(out["packed"]),
            gates={"dx_rel_err": Gate(abs=1e-5, direction="high"),
                   "dw_rel_err": Gate(abs=1e-5, direction="high")},
        ),
        BenchResult(
            name="quant_bench/encode_timing",
            value=out["timing"]["nsd_roundtrip_us"], unit="us",
            derived=dict(out["timing"]),
            gates={},  # wall clock on shared runners: trajectory only
        ),
        BenchResult(
            name="quant_bench/grad_codec_int4",
            value=arms["grad_int4"]["us_per_step"], unit="us/step",
            derived={
                "acc": arms["grad_int4"]["acc"],
                "dacc": arms["grad_int4"]["acc"] - arms["paper"]["acc"],
                "paper_acc": arms["paper"]["acc"],
                "final_loss": arms["grad_int4"]["final_loss"],
            },
            gates={"acc": Gate(abs=10.0, direction="low"),
                   "dacc": Gate(abs=8.0, direction="low")},
        ),
        BenchResult(
            name="quant_bench/moments",
            value=arms["adamw_m8u8"]["us_per_step"], unit="us/step",
            derived={
                "adamw_m8u8_acc": arms["adamw_m8u8"]["acc"],
                "adamw_fp32_acc": arms["adamw_fp32"]["acc"],
                "adamw_dacc": (arms["adamw_m8u8"]["acc"]
                               - arms["adamw_fp32"]["acc"]),
                "sgd_m8_acc": arms["sgd_m8"]["acc"],
                "sgd_m8_dacc": arms["sgd_m8"]["acc"] - arms["paper"]["acc"],
            },
            gates={"adamw_m8u8_acc": Gate(abs=10.0, direction="low"),
                   "adamw_dacc": Gate(abs=8.0, direction="low"),
                   "sgd_m8_dacc": Gate(abs=8.0, direction="low")},
        ),
    ]
    return results


if __name__ == "__main__":
    for r in bench(quick=True):
        print(r.name, f"{r.value:.2f}{r.unit}", r.derived_str())
