"""Shared benchmark utilities: timed training runs with dither telemetry."""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import DitherCtx, DitherPolicy
from repro.core import stats as statslib
from repro.data import ClassifConfig, classification_batch
from repro.models.api import Model
from repro.models.cnn import accuracy
from repro.optim import OptConfig, apply_updates, init_opt_state


def train_classifier(model: Model, policy: Optional[DitherPolicy], *,
                     steps: int = 60, batch: int = 64, lr: float = 0.05,
                     seed: int = 0, noise: float = 0.5,
                     img: Optional[Tuple[int, int]] = None,
                     n_classes: int = 10) -> Dict[str, float]:
    """Paper-recipe SGD training on the synthetic classification set.

    Returns acc%, mean dither sparsity%, worst-case bits, us/step.
    """
    if policy is not None and policy.collect_stats:
        statslib.reset()
    cfg = model.cfg
    img_size, channels = (cfg.img_size, cfg.in_channels) if img is None else img
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)
    opt_cfg = OptConfig(name="sgd", lr=lr, momentum=0.9, weight_decay=5e-4,
                        grad_clip=None, schedule="step",
                        step_decay_every=max(steps // 2, 1),
                        step_decay_rate=0.1)
    state = init_opt_state(params, opt_cfg)
    dcfg = ClassifConfig(n_classes=n_classes, img_size=img_size,
                         channels=channels, noise=noise, seed=seed)

    @jax.jit
    def step_fn(params, state, b, bk):
        ctx = (DitherCtx.for_step(bk, state["step"], policy)
               if policy is not None and policy.enabled else None)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, b, ctx=ctx))(params)
        params, state, _ = apply_updates(params, grads, state, opt_cfg)
        return params, state, loss

    # warmup/compile
    b0 = classification_batch(dcfg, 0, batch=batch)
    params, state, _ = step_fn(params, state, b0, key)
    t0 = time.perf_counter()
    losses = []
    for i in range(1, steps):
        b = classification_batch(dcfg, i, batch=batch)
        params, state, loss = step_fn(params, state, b, key)
        losses.append(float(loss))
    dt_us = (time.perf_counter() - t0) / max(steps - 1, 1) * 1e6
    test = classification_batch(dcfg, 10**6, batch=512)
    acc = float(accuracy(params, cfg, test)) * 100
    out = {"acc": acc, "us_per_step": dt_us,
           "final_loss": losses[-1] if losses else float("nan")}
    if policy is not None and policy.collect_stats:
        out["sparsity"] = statslib.overall_sparsity() * 100
        out["max_bits"] = statslib.overall_max_bits()
    return out


def measure_baseline_sparsity(model: Model, *, steps: int = 5,
                              batch: int = 64, noise: float = 0.5,
                              seed: int = 0) -> float:
    """Sparsity of the RAW pre-activation gradients (Table-1 'Baseline'
    sparsity column) via the tap probe."""
    from repro.core import probe
    from repro.models.cnn import tap_shapes

    cfg = model.cfg
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)
    dcfg = ClassifConfig(n_classes=cfg.n_classes, img_size=cfg.img_size,
                         channels=cfg.in_channels, noise=noise, seed=seed)
    shapes = tap_shapes(cfg, batch)
    sps = []
    for i in range(steps):
        b = classification_batch(dcfg, i, batch=batch)
        taps = probe.make_taps(shapes)
        grads = probe.grad_wrt_taps(
            lambda p, taps: model.loss(p, b, taps=taps), taps, params)
        for name, g in grads.items():
            sps.append(float(probe.baseline_sparsity(g)))
    return float(np.mean(sps)) * 100
