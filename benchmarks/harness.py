"""Shared benchmark utilities: timed training runs with dither telemetry."""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple, Union

import jax
import numpy as np

from repro.core import DitherCtx, DitherPolicy, PolicyProgram
from repro.obs import metrics as statslib
from repro.core.schedule import ControllerDriver, as_program
from repro.data import ClassifConfig, classification_batch
from repro.models.api import Model
from repro.models.cnn import accuracy
from repro.optim import OptConfig, apply_updates, init_opt_state


def train_classifier(model: Model,
                     policy: Optional[Union[DitherPolicy, PolicyProgram]], *,
                     steps: int = 60, batch: int = 64, lr: float = 0.05,
                     seed: int = 0, noise: float = 0.5,
                     img: Optional[Tuple[int, int]] = None,
                     n_classes: int = 10, memory=None,
                     opt_overrides: Optional[Dict] = None
                     ) -> Dict[str, float]:
    """Paper-recipe SGD training on the synthetic classification set.

    ``policy`` may be a full PolicyProgram (phases retrace at their
    boundaries; knob schedules and the controller ride the compiled step).
    ``memory`` is a repro.memory MemoryPolicy (or spec string) selecting
    each dithered layer's residual codec / remat. ``opt_overrides``
    replaces fields of the recipe OptConfig (e.g. ``{"name": "adamw"}`` or
    moment codecs ``{"mu_codec": "m8"}``) without forking the harness.
    Returns acc%, mean dither sparsity%, worst-case bits, us/step (+ the
    measured residual compression when telemetry is on and a memory policy
    is set).
    """
    import dataclasses
    from repro.memory.policy import as_memory_policy

    program = as_program(policy)
    memory = as_memory_policy(memory)
    collect = program is not None and program.base.collect_stats
    if collect:
        statslib.reset()
    cfg = model.cfg
    img_size, channels = (cfg.img_size, cfg.in_channels) if img is None else img
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)
    opt_cfg = OptConfig(name="sgd", lr=lr, momentum=0.9, weight_decay=5e-4,
                        grad_clip=None, schedule="step",
                        step_decay_every=max(steps // 2, 1),
                        step_decay_rate=0.1)
    if opt_overrides:
        opt_cfg = dataclasses.replace(opt_cfg, **opt_overrides)
    state = init_opt_state(params, opt_cfg)
    dcfg = ClassifConfig(n_classes=n_classes, img_size=img_size,
                         channels=channels, noise=noise, seed=seed)

    def step_body(params, state, b, bk, ctrl, phase_pol):
        ctx = (DitherCtx.for_step(bk, state["step"], phase_pol,
                                  program=program, ctrl=ctrl or None,
                                  memory=memory)
               if phase_pol is not None and program.step_enabled(phase_pol)
               else None)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, b, ctx=ctx))(params)
        params, state, _ = apply_updates(params, grads, state, opt_cfg)
        return params, state, loss

    step_fn = jax.jit(step_body, static_argnames=("phase_pol",))
    ctrl = ControllerDriver(program)
    if ctrl.active:
        ctrl.ensure_init(lambda p, b, ctx: model.loss(p, b, ctx=ctx), params,
                         classification_batch(dcfg, 0, batch=batch))

    def phase_at(i: int):
        return program.phase_policy_at(i) if program is not None else None

    # warmup/compile
    b0 = classification_batch(dcfg, 0, batch=batch)
    params, state, _ = step_fn(params, state, b0, key, ctrl.state,
                               phase_pol=phase_at(0))
    ctrl.tick()
    # time each step body (incl. the loss sync) but keep the controller's
    # host tick — which drains the async telemetry via an effects barrier —
    # OUTSIDE the timed region, so controller runs report step cost, not
    # host-sync overhead
    timed_s = 0.0
    losses = []
    for i in range(1, steps):
        b = classification_batch(dcfg, i, batch=batch)
        t1 = time.perf_counter()
        params, state, loss = step_fn(params, state, b, key, ctrl.state,
                                      phase_pol=phase_at(i))
        losses.append(float(loss))
        timed_s += time.perf_counter() - t1
        ctrl.tick()
    dt_us = timed_s / max(steps - 1, 1) * 1e6
    test = classification_batch(dcfg, 10**6, batch=512)
    acc = float(accuracy(params, cfg, test)) * 100
    out = {"acc": acc, "us_per_step": dt_us,
           "final_loss": losses[-1] if losses else float("nan")}
    if collect:
        out["sparsity"] = statslib.overall_sparsity() * 100
        out["max_bits"] = statslib.overall_max_bits()
        if statslib.memory_tags():
            out["residual_compression"] = (
                statslib.overall_residual_compression(
                    program.base.stats_tag))
    return out


def measure_baseline_sparsity(model: Model, *, steps: int = 5,
                              batch: int = 64, noise: float = 0.5,
                              seed: int = 0) -> float:
    """Sparsity of the RAW pre-activation gradients (Table-1 'Baseline'
    sparsity column) via the tap probe."""
    from repro.core import probe
    from repro.models.cnn import tap_shapes

    cfg = model.cfg
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)
    dcfg = ClassifConfig(n_classes=cfg.n_classes, img_size=cfg.img_size,
                         channels=cfg.in_channels, noise=noise, seed=seed)
    shapes = tap_shapes(cfg, batch)
    sps = []
    for i in range(steps):
        b = classification_batch(dcfg, i, batch=batch)
        taps = probe.make_taps(shapes)
        grads = probe.grad_wrt_taps(
            lambda p, taps: model.loss(p, b, taps=taps), taps, params)
        for name, g in grads.items():
            sps.append(float(probe.baseline_sparsity(g)))
    return float(np.mean(sps)) * 100
