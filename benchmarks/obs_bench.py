"""Observability subsystem benchmarks: bus overhead, monitors, report.

Three claims gated on every PR:

* **bus overhead** — telemetry through the metrics bus (io_callback
  emission from the jitted backward, per-generation stacked-view cache)
  costs a bounded multiple of the telemetry-off step. The gate is on the
  on/off *ratio*, not the raw timing (repo policy: wall-clock is recorded,
  never gated), with a generous band — CI hosts are noisy and the model is
  tiny, so the emission path is a worst-case share of the step.
* **monitor trips** — the health detectors fire deterministically on
  synthetic pathologies (NaN loss, sparsity collapse), the suite
  rate-limits a persisting condition, and escalation raises. Zero-band
  gates: trip counts are exact.
* **report render** — a real training run drains into a run directory
  (``benchmarks/results/obs_run`` so the CI artifact upload keeps it) and
  the offline report renders every expected section from the JSONL alone.
"""
from __future__ import annotations

import gc
import os
import shutil
import time
from typing import List

import numpy as np

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy
from repro.obs import metrics as statslib
from repro.obs.bus import MetricsBus, get_bus, set_bus
from repro.obs.monitor import (LossMonitor, MonitorAlert, MonitorSuite,
                               SparsityMonitor)
from repro.obs.runlog import RunLog, read_run
from repro.obs.report import render
from repro.obs.trace import Tracer

from benchmarks.harness import train_classifier

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")
RUN_DIR = os.path.join(RESULTS_DIR, "obs_run")


def _bus_overhead(quick: bool) -> BenchResult:
    steps = 30 if quick else 100
    model = pm.lenet300100()
    pol_off = DitherPolicy(variant="paper", s=2.0)
    pol_on = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                          stats_tag="obsB/")

    # The telemetry-off step has no host-side per-step work, so its
    # wall-clock drops ~35% once the process (thread pools, allocator,
    # jit cache) is warm, while the on-step's io_callback landings are
    # sensitive to heap/GC state left by whatever ran before in the same
    # process. A single off/on sample therefore measures process history
    # as much as the emission path. Warm both conditions first, then take
    # the min over interleaved repeats — both conditions see the same
    # process state and one-off host spikes are filtered out.
    gc.collect()
    train_classifier(model, pol_off, steps=steps)
    train_classifier(model, pol_on, steps=steps)
    off_us, on_us = [], []
    for _ in range(3):
        off_us.append(train_classifier(model, pol_off,
                                       steps=steps)["us_per_step"])
        on = train_classifier(model, pol_on, steps=steps)
        on_us.append(on["us_per_step"])
    off = {"us_per_step": min(off_us)}
    on["us_per_step"] = min(on_us)
    rows = sum(statslib.row_count(t) for t in statslib.tags()
               if t.startswith("obsB/"))
    ratio = on["us_per_step"] / max(off["us_per_step"], 1e-9)
    return BenchResult(
        name="obs/bus_overhead",
        value=on["us_per_step"],
        derived={
            "overhead_ratio": ratio,
            "us_per_step_off": off["us_per_step"],
            "rows_per_step": rows / max(steps - 1, 1),
        },
        # ratio gate only, generous: LeNet-300-100 steps are ~100us, so the
        # io_callback landing cost is a worst-case fraction of the step;
        # anything beyond baseline + max(75% rel, 1.0 abs) is a real
        # emission-path regression, not host noise
        gates={"overhead_ratio": Gate(rel=0.75, abs=1.0, direction="high")},
        context={"steps": steps, "model": "lenet300100"},
    )


def _monitor_trip() -> BenchResult:
    t0 = time.perf_counter()
    bus = MetricsBus()

    # NaN loss -> one critical trip
    loss_mon = LossMonitor(bus=bus)
    bus.record("train", "train", [1.0, 2.5])
    bus.record("train", "train", [2.0, float("nan")])
    loss_trips = len(loss_mon.tick(2))

    # collapsed sparsity -> one warning; persisting -> rate-limited
    sp_mon = SparsityMonitor(setpoint=0.9, band=0.1, min_rows=1, bus=bus)
    suite = MonitorSuite([sp_mon], reemit_every=100, bus=bus)
    bus.record("dither", "fc0", [0.1, 4.0, 0.1])
    sparsity_trips = len(suite.tick(1))
    reemits = 0
    for s in range(2, 6):
        bus.record("dither", "fc0", [0.1, 4.0, 0.1])
        reemits += len(suite.tick(s))

    # escalation raises on critical
    esc = MonitorSuite([LossMonitor(bus=bus)], escalate=True, bus=bus)
    bus.record("train", "esc", [3.0, float("inf")])
    try:
        esc.tick(3)
        raised = 0.0
    except MonitorAlert:
        raised = 1.0

    dt_us = (time.perf_counter() - t0) * 1e6
    zero = Gate(rel=0.0, abs=0.0, direction="both")
    return BenchResult(
        name="obs/monitor_trip",
        value=dt_us,
        derived={"loss_trips": float(loss_trips),
                 "sparsity_trips": float(sparsity_trips),
                 "rate_limited_reemits": float(reemits),
                 "escalate_raised": raised},
        gates={"loss_trips": zero, "sparsity_trips": zero,
               "rate_limited_reemits": zero, "escalate_raised": zero},
    )


def _report_render(quick: bool) -> BenchResult:
    steps = 25 if quick else 80
    old_bus = get_bus()
    bus = set_bus(MetricsBus())
    try:
        tracer = Tracer(bus)
        with tracer.span("train"):
            res = train_classifier(
                pm.lenet300100(),
                DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                             stats_tag="obsR/"),
                steps=steps, memory="default=nsd")
        bus.record("train", "train", [float(steps), res["final_loss"]])

        shutil.rmtree(RUN_DIR, ignore_errors=True)
        runlog = RunLog(RUN_DIR, bus=bus, context={
            "tool": "obs_bench", "model": "lenet300100", "steps": steps})
        lines = runlog.flush()
        t0 = time.perf_counter()
        text = render(RUN_DIR)
        render_us = (time.perf_counter() - t0) * 1e6
        _, streams = read_run(RUN_DIR)
    finally:
        set_bus(old_bus)

    present = set(streams)
    return BenchResult(
        name="obs/report_render",
        value=render_us,
        derived={
            "jsonl_lines": float(lines),
            "report_chars": float(len(text)),
            # zero-band presence flags: the report must have every section
            # a dithered + memory-policied run produces
            "has_dither": float("dither" in present),
            "has_memory": float("memory" in present),
            "has_phase": float("phase" in present),
            "has_train": float("train" in present),
            "overall_sparsity_pct": float(np.mean(
                [r["sparsity"] for r in streams.get("dither", [])
                 if r.get("sparsity") is not None]) * 100),
        },
        gates={
            "has_dither": Gate(direction="both"),
            "has_memory": Gate(direction="both"),
            "has_phase": Gate(direction="both"),
            "has_train": Gate(direction="both"),
            "jsonl_lines": Gate(rel=0.0, abs=0.0, direction="both"),
            "overall_sparsity_pct": Gate(rel=0.0, abs=3.0,
                                         direction="both"),
        },
        context={"steps": steps, "run_dir": "benchmarks/results/obs_run"},
    )


def bench(quick: bool = True) -> List[BenchResult]:
    return [_bus_overhead(quick), _monitor_trip(), _report_render(quick)]
