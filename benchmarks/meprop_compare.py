"""Paper fig. 4 / fig. .9: dithered backprop vs meProp at matched sparsity
on the MLP-(500,500) protocol. Expectation (the paper's claim): unbiased
dither dominates biased top-k at every sparsity level."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import paper_models as pm
from repro.core import DitherPolicy

from benchmarks.harness import train_classifier


def run(steps: int = 60) -> List[Dict]:
    rows = []
    model = pm.mlp_mnist(hidden=(500, 500))
    for s in (1.0, 2.0, 4.0, 8.0):
        pol = DitherPolicy(variant="paper", s=s, collect_stats=True,
                           stats_tag=f"fig4/d{s}/")
        r = train_classifier(model, pol, steps=steps)
        rows.append({"method": "dithered", "knob": s, "acc": r["acc"],
                     "sparsity": r.get("sparsity", float("nan")),
                     "us": r["us_per_step"]})
    for k in (0.3, 0.1, 0.03, 0.01):
        pol = DitherPolicy(variant="meprop", meprop_k_frac=k,
                           collect_stats=True, stats_tag=f"fig4/m{k}/")
        r = train_classifier(model, pol, steps=steps)
        rows.append({"method": "meprop", "knob": k, "acc": r["acc"],
                     "sparsity": r.get("sparsity", float("nan")),
                     "us": r["us_per_step"]})
    return rows


def bench(quick: bool = True):
    rows = run(steps=40 if quick else 100)
    out = []
    for r in rows:
        out.append((
            f"fig4/{r['method']}@{r['knob']}", r["us"],
            f"acc={r['acc']:.1f}% sparsity={r['sparsity']:.1f}%"))
    return out


def bench_hard(quick: bool = True):
    """fig4 on a HARD synthetic task (8x8, noise 3.0): the paper's ordering
    claim shows starkly here — biased top-k collapses while unbiased dither
    tracks the baseline. (The default task saturates at 100% accuracy and
    cannot discriminate.)"""
    from repro.models.api import cnn_model
    from repro.models.cnn import CNNConfig

    def model():
        return cnn_model(CNNConfig(name="mlp-hard", arch="mlp", n_classes=10,
                                   in_channels=1, img_size=8,
                                   hidden=(256, 256)))

    steps = 60 if quick else 150
    out = []
    r = train_classifier(model(), None, steps=steps, noise=3.0)
    out.append(("fig4-hard/baseline", r["us_per_step"],
                f"acc={r['acc']:.1f}%"))
    for s in (2.0, 4.0, 8.0):
        pol = DitherPolicy(variant="paper", s=s, collect_stats=True,
                           stats_tag=f"f4h/d{s}/")
        r = train_classifier(model(), pol, steps=steps, noise=3.0)
        out.append((f"fig4-hard/dithered@s={s:g}", r["us_per_step"],
                    f"acc={r['acc']:.1f}% sparsity={r.get('sparsity', 0):.1f}%"))
    for k in (0.1, 0.03, 0.01):
        pol = DitherPolicy(variant="meprop", meprop_k_frac=k,
                           collect_stats=True, stats_tag=f"f4h/m{k}/")
        r = train_classifier(model(), pol, steps=steps, noise=3.0)
        out.append((f"fig4-hard/meprop@k={k:g}", r["us_per_step"],
                    f"acc={r['acc']:.1f}% sparsity={r.get('sparsity', 0):.1f}%"))
    return out
