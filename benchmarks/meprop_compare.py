"""Paper fig. 4 / fig. .9: dithered backprop vs meProp at matched sparsity
on the MLP-(500,500) protocol. Expectation (the paper's claim): unbiased
dither dominates biased top-k at every sparsity level."""
from __future__ import annotations

from typing import Dict, List

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import DitherPolicy

from benchmarks.harness import train_classifier


def run(steps: int = 60) -> List[Dict]:
    rows = []
    model = pm.mlp_mnist(hidden=(500, 500))
    for s in (1.0, 2.0, 4.0, 8.0):
        pol = DitherPolicy(variant="paper", s=s, collect_stats=True,
                           stats_tag=f"fig4/d{s}/")
        r = train_classifier(model, pol, steps=steps)
        rows.append({"method": "dithered", "knob": s, "acc": r["acc"],
                     "sparsity": r.get("sparsity", float("nan")),
                     "us": r["us_per_step"]})
    for k in (0.3, 0.1, 0.03, 0.01):
        pol = DitherPolicy(variant="meprop", meprop_k_frac=k,
                           collect_stats=True, stats_tag=f"fig4/m{k}/")
        r = train_classifier(model, pol, steps=steps)
        rows.append({"method": "meprop", "knob": k, "acc": r["acc"],
                     "sparsity": r.get("sparsity", float("nan")),
                     "us": r["us_per_step"]})
    return rows


def bench(quick: bool = True) -> List[BenchResult]:
    """One result per (method, operating point). Dithered points gate both
    accuracy and sparsity; meProp points gate only accuracy (their sparsity
    is the dialed-in k, not a claim)."""
    rows = run(steps=40 if quick else 100)
    out = []
    for r in rows:
        gates = {"acc": Gate(abs=10.0, direction="low")}
        if r["method"] == "dithered":
            gates["sparsity"] = Gate(abs=8.0, direction="low")
        out.append(BenchResult(
            name=f"fig4/{r['method']}@{r['knob']}",
            value=r["us"],
            unit="us/step",
            derived={"acc": r["acc"], "sparsity": r["sparsity"]},
            gates=gates,
        ))
    return out


def bench_hard(quick: bool = True) -> List[BenchResult]:
    """fig4 on a HARD synthetic task (8x8, noise 3.0): the paper's ordering
    claim shows starkly here — biased top-k collapses while unbiased dither
    tracks the baseline. (The default task saturates at 100% accuracy and
    cannot discriminate.) The hard task is noisier than the default, so
    accuracy bands are wider, and meProp points are ungated entirely —
    their collapse is the expected result, not a regression.
    """
    from repro.models.api import cnn_model
    from repro.models.cnn import CNNConfig

    def model():
        return cnn_model(CNNConfig(name="mlp-hard", arch="mlp", n_classes=10,
                                   in_channels=1, img_size=8,
                                   hidden=(256, 256)))

    steps = 60 if quick else 150
    out = []
    r = train_classifier(model(), None, steps=steps, noise=3.0)
    out.append(BenchResult(
        name="fig4-hard/baseline", value=r["us_per_step"], unit="us/step",
        derived={"acc": r["acc"]},
        gates={"acc": Gate(abs=20.0, direction="low")}))
    for s in (2.0, 4.0, 8.0):
        pol = DitherPolicy(variant="paper", s=s, collect_stats=True,
                           stats_tag=f"f4h/d{s}/")
        r = train_classifier(model(), pol, steps=steps, noise=3.0)
        out.append(BenchResult(
            name=f"fig4-hard/dithered@s={s:g}", value=r["us_per_step"],
            unit="us/step",
            derived={"acc": r["acc"], "sparsity": r.get("sparsity", 0.0)},
            gates={"acc": Gate(abs=20.0, direction="low"),
                   "sparsity": Gate(abs=8.0, direction="low")}))
    for k in (0.1, 0.03, 0.01):
        pol = DitherPolicy(variant="meprop", meprop_k_frac=k,
                           collect_stats=True, stats_tag=f"f4h/m{k}/")
        r = train_classifier(model(), pol, steps=steps, noise=3.0)
        out.append(BenchResult(
            name=f"fig4-hard/meprop@k={k:g}", value=r["us_per_step"],
            unit="us/step",
            derived={"acc": r["acc"], "sparsity": r.get("sparsity", 0.0)}))
    return out
