"""The one benchmark runner: execute suites, persist BENCH_*.json, gate.

    PYTHONPATH=src python -m benchmarks.suite [--full] [--only a,b]
        [--check] [--rebaseline] [--results-dir D] [--baseline-dir D]

Each suite under ``benchmarks/`` exposes ``bench(quick) ->
List[BenchResult]``; this runner wraps the results in a provenance-stamped
``SuiteRun`` (git sha, jax version, backend, quick flag) and writes
``BENCH_<suite>.json`` to ``benchmarks/results/`` — the machine-readable
perf trajectory the repo previously lacked.

``--check`` compares every run against the committed baseline in
``benchmarks/baselines/`` with the per-metric tolerance bands the suites
declare (``repro.bench.compare`` policy: missing bench or out-of-band
gated metric fails; new bench / absent baseline file passes) and exits
non-zero on any regression. ``--rebaseline`` copies the fresh results
over the committed baselines — rerun it after an intentional perf or
metric change and commit the diff.

A suite that raises is reported with a full traceback and the runner
exits non-zero (``status: error`` in the summary) — exceptions are never
swallowed into a green exit code. ``benchmarks.run`` is a thin CSV
front-end over this module; there is exactly one runner.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback
from typing import Dict, List, Optional, Tuple

from repro.bench import SuiteRun, compare_runs, make_suite_run

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS_DIR = os.path.join(HERE, "results")
BASELINE_DIR = os.path.join(HERE, "baselines")


def _suite_fns() -> Dict[str, callable]:
    """Import lazily so ``--help`` stays fast and import errors surface
    per-suite rather than killing the whole runner."""
    from benchmarks import (complexity, convergence, distributed_nodes,
                            hillclimb, kernel_bench, layer_sparsity,
                            memory_bench, meprop_compare, obs_bench,
                            quant_bench, roofline_table, serve_bench,
                            table1_sparsity)

    def meprop_both(quick: bool = True):
        return (meprop_compare.bench(quick=quick)
                + meprop_compare.bench_hard(quick=quick))

    return {
        "table1_sparsity": table1_sparsity.bench,
        "layer_sparsity": layer_sparsity.bench,
        "memory_bench": memory_bench.bench,
        "convergence": convergence.bench,
        "meprop_compare": meprop_both,
        "distributed_nodes": distributed_nodes.bench,
        "kernel_bench": kernel_bench.bench,
        "complexity": complexity.bench,
        "roofline_table": roofline_table.bench,
        "hillclimb": hillclimb.bench,
        "obs_bench": obs_bench.bench,
        "serve_bench": serve_bench.bench,
        "quant_bench": quant_bench.bench,
    }


SUITE_NAMES = ("table1_sparsity", "layer_sparsity", "memory_bench",
               "convergence", "meprop_compare", "distributed_nodes",
               "kernel_bench", "complexity", "roofline_table", "hillclimb",
               "obs_bench", "serve_bench", "quant_bench")


def result_path(suite: str, results_dir: str = RESULTS_DIR) -> str:
    return os.path.join(results_dir, f"BENCH_{suite}.json")


def baseline_path(suite: str, baseline_dir: str = BASELINE_DIR) -> str:
    return os.path.join(baseline_dir, f"BENCH_{suite}.json")


def write_run(run: SuiteRun, path: str) -> str:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        # allow_nan=False: a NaN metric would serialize as a bare `NaN`
        # literal, making the artifact unreadable for strict JSON parsers
        # (jq, JS) — fail loudly at write time instead
        json.dump(run.to_dict(), f, indent=1, sort_keys=True,
                  allow_nan=False)
        f.write("\n")
    return path


def load_run(path: str) -> Optional[SuiteRun]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return SuiteRun.from_dict(json.load(f))


def run_suites(names: List[str], *, quick: bool = True,
               results_dir: str = RESULTS_DIR
               ) -> Tuple[Dict[str, SuiteRun], List[str]]:
    """Execute ``names`` in order; returns (completed runs, failed names).

    A failing suite gets its traceback printed and is recorded in the
    failure list — never silently skipped, never fatal to later suites.
    """
    fns = _suite_fns()
    runs: Dict[str, SuiteRun] = {}
    failed: List[str] = []
    for name in names:
        print(f"[suite] running {name} ({'quick' if quick else 'full'})",
              file=sys.stderr, flush=True)
        try:
            results = fns[name](quick=quick)
            run = make_suite_run(name, results, quick=quick)
            # inside the try: a NaN metric makes write_run's strict json
            # raise, which must fail THIS suite, not abort the rest
            path = write_run(run, result_path(name, results_dir))
        except Exception:
            traceback.print_exc()
            print(f"[suite] {name}: ERROR (see traceback above)",
                  file=sys.stderr, flush=True)
            failed.append(name)
            continue
        print(f"[suite] {name}: {len(run.results)} results -> {path}",
              file=sys.stderr, flush=True)
        runs[name] = run
    return runs, failed


def check_runs(runs: Dict[str, SuiteRun], *,
               baseline_dir: str = BASELINE_DIR,
               verbose: bool = False) -> List[str]:
    """Compare runs against committed baselines; returns failing suites."""
    failing = []
    for name, run in runs.items():
        base = load_run(baseline_path(name, baseline_dir))
        report = compare_runs(run, base)
        print(report.render(verbose=verbose), flush=True)
        if not report.ok:
            failing.append(name)
    return failing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run benchmark suites, write BENCH_*.json, gate "
                    "against committed baselines")
    ap.add_argument("--full", action="store_true",
                    help="full model set + longer runs (default: quick)")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; kept so CI "
                    "invocations self-document)")
    ap.add_argument("--only", "--suites", dest="only", default="",
                    help=f"comma list of suites from: {','.join(SUITE_NAMES)}"
                    ". Combine with --rebaseline to refresh ONLY the "
                    "affected suites' baselines — a blanket rebaseline "
                    "would also shift every other suite's bands to "
                    "whatever this host happened to measure.")
    ap.add_argument("--check", action="store_true",
                    help="compare against committed baselines; exit "
                    "non-zero on regression")
    ap.add_argument("--rebaseline", action="store_true",
                    help="copy this run's results over the committed "
                    "baselines (then commit the diff)")
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--verbose", action="store_true",
                    help="print every comparison, not just notable ones")
    args = ap.parse_args(argv)
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")

    names = [n for n in args.only.split(",") if n] or list(SUITE_NAMES)
    unknown = [n for n in names if n not in SUITE_NAMES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; known: {SUITE_NAMES}")

    runs, failed = run_suites(names, quick=not args.full,
                              results_dir=args.results_dir)
    rc = 0
    if failed:
        print(f"[suite] FAILED suites: {failed}", flush=True)
        rc = 1

    # check BEFORE rebaseline: `--rebaseline --check` must report drift
    # against the OLD committed baselines, not the ones this run is about
    # to write — otherwise the combination is a vacuous always-green gate
    if args.check:
        failing = check_runs(runs, baseline_dir=args.baseline_dir,
                             verbose=args.verbose)
        if failing:
            print(f"[suite] perf gate FAILED: {failing}", flush=True)
            rc = 1
        else:
            print("[suite] perf gate OK", flush=True)

    if args.rebaseline:
        for name, run in runs.items():
            path = write_run(run, baseline_path(name, args.baseline_dir))
            print(f"[suite] rebaselined {name} -> {path}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
