"""Benchmark runner — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV rows. Paper mapping:
  table1     -> Table 1 (acc + sparsity, 4 methods x models)
  fig3       -> Fig 3/.7/.8 (convergence parity)
  fig4       -> Fig 4/.9 (dither vs meProp at matched sparsity)
  fig5-6     -> Fig 5/6/.10/.11 (distributed: s(N) scaling)
  kern       -> kernel microbenches (tile-skip & int8 path)
  roofline   -> dry-run roofline table (deliverable g)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full model set + longer runs")
    ap.add_argument("--only", default="",
                    help="comma list: table1,fig3,fig4,fig5-6,kern,roofline")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (complexity, convergence, distributed_nodes,
                            kernel_bench, meprop_compare, roofline_table,
                            table1_sparsity)

    suites = {
        "table1": table1_sparsity.bench,
        "fig3": convergence.bench,
        "fig4": meprop_compare.bench,
        "fig4-hard": meprop_compare.bench_hard,
        "fig5-6": distributed_nodes.bench,
        "kern": kernel_bench.bench,
        "complexity": complexity.bench,
        "roofline": roofline_table.bench,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if only is not None and name not in only:
            continue
        try:
            for row_name, us, derived in fn(quick=quick):
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},nan,SUITE_FAILED")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
