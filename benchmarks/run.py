"""Legacy CSV front-end over ``benchmarks.suite`` (the one runner).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV rows exactly as before; execution,
JSON persistence and error handling all live in ``benchmarks.suite`` (a
suite that raises prints its traceback there and this process exits
non-zero — failures are never swallowed into a green exit). For the
regression gate use ``python -m benchmarks.suite --check``.

Paper mapping of the legacy names:
  table1     -> Table 1 (acc + sparsity, 4 methods x models)
  fig3       -> Fig 3/.7/.8 (convergence parity)
  fig4       -> Fig 4/.9 (dither vs meProp, incl. hard-task variant)
  fig5-6     -> Fig 5/6/.10/.11 (distributed: s(N) scaling)
  kern       -> kernel microbenches (tile-skip, int8 path, bitmap pack)
  roofline   -> dry-run roofline table (deliverable g)
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import suite as suitelib

# legacy CLI name -> suite.py name. NOTE: fig4 and fig4-hard are both
# aliases for the combined meprop_compare suite (suite granularity is the
# unit of execution and baselining now), so selecting either runs the
# standard sweep AND the hard-task variant; `--only fig4,fig4-hard` runs
# the suite once, not twice.
LEGACY_NAMES = {
    "table1": "table1_sparsity",
    "fig3": "convergence",
    "fig4": "meprop_compare",
    "fig4-hard": "meprop_compare",
    "fig5-6": "distributed_nodes",
    "kern": "kernel_bench",
    "complexity": "complexity",
    "roofline": "roofline_table",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full model set + longer runs")
    ap.add_argument("--only", default="",
                    help=f"comma list: {','.join(LEGACY_NAMES)}")
    args = ap.parse_args()

    names: list = []
    for legacy in (args.only.split(",") if args.only else LEGACY_NAMES):
        if legacy not in LEGACY_NAMES:
            ap.error(f"unknown suite {legacy!r}; known: "
                     f"{','.join(LEGACY_NAMES)}")
        mapped = LEGACY_NAMES[legacy]
        if mapped not in names:
            names.append(mapped)

    print("name,us_per_call,derived")
    runs, failed = suitelib.run_suites(names, quick=not args.full)
    for run in runs.values():
        for r in run.results:
            print(f"{r.name},{r.value:.1f},{r.derived_str()}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
