"""Fig. 4-style per-layer sparsity-over-training table (policy programs).

The paper's Fig. 4/5 show pre-activation-gradient sparsity varying per
layer and per training phase. This suite drives LeNet-300-100 through a
:class:`repro.core.schedule.PolicyProgram` and gates three claims on every
PR:

* **parity** — a program whose only rule is the universal ``LayerRule()``
  reproduces the global ``DitherPolicy`` telemetry (sparsity, bit-width,
  delta of every layer x step record) **bit-for-bit**: the gate band is
  exactly zero.
* **per-layer table** — with an ``s`` ramp 1.0 -> 4.0 and a rule pinning
  ``fc0`` at s=4.0 from step 0, each layer's sparsity trajectory over
  training windows stays in band, and the early-training contrast between
  the pinned and the ramped layers (~10 sparsity points) stays open — if
  per-layer resolution ever broke, fc0 would fall onto the ramp and the
  contrast gate would close.
* **controller** — the closed-loop sparsity controller lands each layer's
  measured sparsity within a few points of its target.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.bench import BenchResult, Gate
from repro.configs import paper_models as pm
from repro.core import (DitherPolicy, LayerRule, Linear, PolicyProgram,
                        SparsityController)
from repro.obs import metrics as statslib

from benchmarks.harness import train_classifier

LAYERS = ("fc0", "fc1", "fc2")
N_WINDOWS = 3


def _window_sparsity(tag: str, n_windows: int = N_WINDOWS) -> List[float]:
    """Mean sparsity%% of a layer's telemetry rows split into step windows."""
    rows = statslib.rows(tag)
    if len(rows) == 0:
        return [float("nan")] * n_windows
    splits = np.array_split(rows[:, 0], n_windows)
    return [float(w.mean()) * 100 for w in splits]


def _snapshot(tags_prefix: str) -> Dict[str, np.ndarray]:
    """All telemetry rows under a tag prefix, keyed by layer name."""
    out = {}
    for tag in statslib.tags():
        if tag.startswith(tags_prefix):
            out[tag[len(tags_prefix):]] = statslib.rows(tag).copy()
    return out


def run(quick: bool = True) -> Dict[str, Dict]:
    steps = 40 if quick else 120
    model = pm.lenet300100()

    # ---- parity: global policy vs single-universal-rule program ----------
    global_pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                              stats_tag="lsG/")
    res_global = train_classifier(model, global_pol, steps=steps)
    rows_global = _snapshot("lsG/")

    prog_universal = PolicyProgram(
        base=DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                          stats_tag="lsP/"),
        rules=(LayerRule(),))
    res_prog = train_classifier(model, prog_universal, steps=steps)
    rows_prog = _snapshot("lsP/")

    diffs = []
    for layer in LAYERS:
        a, b = rows_global.get(layer), rows_prog.get(layer)
        if a is None or b is None or a.shape != b.shape:
            diffs.append(float("inf"))
        else:
            diffs.append(float(np.max(np.abs(a - b))) if a.size else 0.0)
    parity = {
        "max_abs_diff": max(diffs),
        "global_sparsity": res_global.get("sparsity", float("nan")),
        "program_sparsity": res_prog.get("sparsity", float("nan")),
        "us_per_step": res_prog["us_per_step"],
    }

    # ---- per-layer table: s ramp 1->4 with fc0 rule-pinned at s=4 --------
    prog_sched = PolicyProgram(
        base=DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                          stats_tag="lsS/"),
        s=Linear(0, steps, 1.0, 4.0),
        rules=(LayerRule(pattern="fc0", s=4.0),))
    res_sched = train_classifier(model, prog_sched, steps=steps)
    table: Dict[str, Dict] = {}
    for layer in LAYERS:
        wins = _window_sparsity(f"lsS/{layer}")
        table[layer] = {
            "windows": wins,
            "ramp_delta": wins[-1] - wins[0],
            "us_per_step": res_sched["us_per_step"],
        }
    # early-window contrast: the rule-pinned layer starts at s=4 while the
    # ramp is still at s~1 — this is what proves per-layer resolution
    contrast = table["fc0"]["windows"][0] - table["fc1"]["windows"][0]

    # ---- closed-loop controller ------------------------------------------
    target = 0.93
    prog_ctl = PolicyProgram(
        base=DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                          stats_tag="lsC/"),
        controller=SparsityController(target=target, gain=4.0))
    train_classifier(model, prog_ctl, steps=steps)
    gaps = []
    for layer in LAYERS:
        final = _window_sparsity(f"lsC/{layer}")[-1]
        gaps.append(abs(final - target * 100))
    controller = {"target_pct": target * 100,
                  "max_final_gap_pct": max(gaps)}

    return {"parity": parity, "table": table, "contrast": contrast,
            "controller": controller}


def bench(quick: bool = True) -> List[BenchResult]:
    out = run(quick=quick)
    results = [BenchResult(
        name="layer_sparsity/parity",
        value=out["parity"]["us_per_step"],
        unit="us/step",
        derived={
            "max_abs_diff": out["parity"]["max_abs_diff"],
            "global_sparsity": out["parity"]["global_sparsity"],
            "program_sparsity": out["parity"]["program_sparsity"],
        },
        gates={
            # the acceptance bar: universal-rule program == global policy,
            # bit for bit — the band is exactly zero
            "max_abs_diff": Gate(abs=0.0, direction="both"),
            "program_sparsity": Gate(abs=8.0, direction="low"),
        },
    )]
    for layer, row in out["table"].items():
        derived = {f"w{i}_sparsity": w for i, w in enumerate(row["windows"])}
        derived["ramp_delta"] = row["ramp_delta"]
        if layer == "fc0":
            # rule-pinned at s=4.0 from step 0: a deterministic trajectory —
            # drift in either direction means per-layer resolution broke
            gates = {f"w{i}_sparsity": Gate(abs=6.0, direction="both")
                     for i in range(N_WINDOWS)}
        else:
            # ramped layers: sparsity must keep rising across windows
            gates = {f"w{i}_sparsity": Gate(abs=8.0, direction="low")
                     for i in range(N_WINDOWS)}
            gates["ramp_delta"] = Gate(abs=8.0, direction="low")
        results.append(BenchResult(
            name=f"layer_sparsity/{layer}",
            value=row["us_per_step"],
            unit="us/step",
            derived=derived,
            gates=gates,
        ))
    results.append(BenchResult(
        name="layer_sparsity/rule_contrast",
        value=0.0,
        unit="us",
        derived={"fc0_w0_minus_fc1_w0": out["contrast"]},
        # the pinned-vs-ramped early gap (~10 points) must stay open
        gates={"fc0_w0_minus_fc1_w0": Gate(abs=4.0, direction="low")},
    ))
    results.append(BenchResult(
        name="layer_sparsity/controller",
        value=0.0,
        unit="us",
        derived={
            "target_pct": out["controller"]["target_pct"],
            "max_final_gap_pct": out["controller"]["max_final_gap_pct"],
        },
        gates={"max_final_gap_pct": Gate(abs=5.0, direction="high")},
    ))
    return results
