"""Paper §3.6 demo: more nodes -> stronger dither -> sparser per-node
backprop at flat accuracy.

    PYTHONPATH=src python examples/distributed_dither.py
"""
from benchmarks.distributed_nodes import run

rows = run(node_counts=(1, 2, 4), steps=30)
print(f"{'N':>3s} {'s':>6s} {'acc%':>7s} {'sparsity%':>10s} {'bits':>5s}")
for r in rows:
    print(f"{r['n_nodes']:3d} {r['s']:6.2f} {r['acc']:7.2f} "
          f"{r['sparsity']:10.2f} {r['max_bits']:5.0f}")
print("(expected: sparsity rises with N, accuracy approximately flat)")
