"""End-to-end compressed gradient exchange (paper §3.6 + §distributed).

Demonstrates the full ``repro.comm`` stack on real model gradients:

  1. N simulated nodes compute dithered per-node gradients (SSGD).
  2. Each node's gradient pytree goes on the wire in packed NSD format —
     the run() table reports measured bytes vs dense f32 and the priced
     interconnect time on TPU v5e.
  3. One layer's gradient additionally goes through the compressed RING
     all-reduce (re-dithered partial sums, per-hop keys) and the result is
     checked against the dense average within the documented NSD bound.
  4. The same gradients cross the two-level HIERARCHICAL reduce (intra-pod
     ring + inter-pod tree): fewer sequential packs per segment, a tighter
     error bound, and the wire split into ICI vs DCN bytes priced at their
     separate bandwidths.

    PYTHONPATH=src:. python examples/distributed_dither.py
"""
import jax
import jax.numpy as jnp

from benchmarks.distributed_nodes import compare_topologies, run
from repro.comm import RingConfig, ring_allreduce_nsd

# --- part 1+2: SSGD scaling table with wire telemetry ---
rows = run(node_counts=(1, 2, 4), steps=30)
print(f"{'N':>3s} {'s':>6s} {'acc%':>7s} {'sparsity%':>10s} {'bits':>5s} "
      f"{'wire%':>6s} {'linkx':>6s}")
for r in rows:
    wire = f"{r.get('wire_ratio', float('nan')) * 100:6.1f}"
    spd = f"{r.get('comm_speedup', float('nan')):6.1f}"
    print(f"{r['n_nodes']:3d} {r['s']:6.2f} {r['acc']:7.2f} "
          f"{r['sparsity']:10.2f} {r['max_bits']:5.0f} {wire} {spd}")
print("(expected: sparsity rises with N, accuracy approximately flat, "
      "wire% falls)")

# --- part 3: compressed ring all-reduce on a gradient-sized tensor ---
key = jax.random.PRNGKey(0)
n_nodes = 4
grads = jnp.stack([
    jax.random.normal(jax.random.fold_in(key, i), (256, 256)) * 0.01
    for i in range(n_nodes)])
mean, tele = ring_allreduce_nsd(grads, key, RingConfig(s=1.0))
dense_mean = jnp.mean(grads, axis=0)
err = float(jnp.max(jnp.abs(mean - dense_mean)))
print(f"\nring all-reduce over {n_nodes} nodes, 256x256 grad:")
print(f"  max |err| vs dense mean : {err:.3e} "
      f"(documented bound {float(tele.error_bound):.3e})")
print(f"  bytes on wire           : {float(tele.wire_bytes):,.0f} "
      f"({float(tele.ratio) * 100:.1f}% of dense f32 ring)")
assert err <= float(tele.error_bound), "NSD ring exceeded its error bound"

# --- part 4: two-level reduce vs flat ring at pod scale (N=8, 2 pods) ---
cmp = compare_topologies(n_nodes=8, pods=2, s=1.0)
by_topo = {r["topology"]: r for r in cmp["rows"]}
print(f"\nflat ring vs hierarchical reduce, {cmp['n_nodes']} nodes in "
      f"{cmp['pods']} pods:")
for name in ("ring", "hier"):
    r = by_topo[name]
    print(f"  {name}: packs/segment={r['packs_per_segment']:2d} "
          f"bound={r['error_bound']:.3e} err={r['max_err']:.3e} "
          f"wire={r['wire_bytes']:,.0f}B "
          f"modeled ici={r['ici_s'] * 1e6:.1f}us "
          f"dcn={r['dcn_s'] * 1e6:.1f}us "
          f"total={r['total_s'] * 1e6:.1f}us")
    assert r["max_err"] <= r["error_bound"], \
        f"{name} exceeded its error bound"
assert by_topo["hier"]["packs_per_segment"] < \
    by_topo["ring"]["packs_per_segment"]
assert by_topo["hier"]["error_bound"] < by_topo["ring"]["error_bound"], \
    "hierarchy should tighten the bound at pod scale"
print("(expected: hier re-quantizes each segment fewer times -> tighter "
      "bound, and its DCN traffic is a small fraction of the wire)")
