"""End-to-end compressed gradient exchange (paper §3.6 + §distributed).

Demonstrates the full ``repro.comm`` stack on real model gradients:

  1. N simulated nodes compute dithered per-node gradients (SSGD).
  2. Each node's gradient pytree goes on the wire in packed NSD format —
     the run() table reports measured bytes vs dense f32 and the priced
     interconnect time on TPU v5e.
  3. One layer's gradient additionally goes through the compressed RING
     all-reduce (re-dithered partial sums, per-hop keys) and the result is
     checked against the dense average within the documented NSD bound.

    PYTHONPATH=src:. python examples/distributed_dither.py
"""
import jax
import jax.numpy as jnp

from benchmarks.distributed_nodes import run
from repro.comm import RingConfig, ring_allreduce_nsd

# --- part 1+2: SSGD scaling table with wire telemetry ---
rows = run(node_counts=(1, 2, 4), steps=30)
print(f"{'N':>3s} {'s':>6s} {'acc%':>7s} {'sparsity%':>10s} {'bits':>5s} "
      f"{'wire%':>6s} {'linkx':>6s}")
for r in rows:
    wire = f"{r.get('wire_ratio', float('nan')) * 100:6.1f}"
    spd = f"{r.get('comm_speedup', float('nan')):6.1f}"
    print(f"{r['n_nodes']:3d} {r['s']:6.2f} {r['acc']:7.2f} "
          f"{r['sparsity']:10.2f} {r['max_bits']:5.0f} {wire} {spd}")
print("(expected: sparsity rises with N, accuracy approximately flat, "
      "wire% falls)")

# --- part 3: compressed ring all-reduce on a gradient-sized tensor ---
key = jax.random.PRNGKey(0)
n_nodes = 4
grads = jnp.stack([
    jax.random.normal(jax.random.fold_in(key, i), (256, 256)) * 0.01
    for i in range(n_nodes)])
mean, tele = ring_allreduce_nsd(grads, key, RingConfig(s=1.0))
dense_mean = jnp.mean(grads, axis=0)
err = float(jnp.max(jnp.abs(mean - dense_mean)))
print(f"\nring all-reduce over {n_nodes} nodes, 256x256 grad:")
print(f"  max |err| vs dense mean : {err:.3e} "
      f"(documented bound {float(tele.error_bound):.3e})")
print(f"  bytes on wire           : {float(tele.wire_bytes):,.0f} "
      f"({float(tele.ratio) * 100:.1f}% of dense f32 ring)")
assert err <= float(tele.error_bound), "NSD ring exceeded its error bound"
