"""End-to-end driver: train a ~100M-parameter LM with dithered backprop.

    # real thing (a few hundred steps; give it a beefy machine or TPU):
    PYTHONPATH=src python examples/train_lm.py --steps 300

    # CPU-friendly demo of the same pipeline:
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 40

Exercises the full production path: model zoo config -> dither policy ->
trainer (grad accum, ckpt, preemption guard) -> synthetic token pipeline.
"""
import argparse

import jax.numpy as jnp

from repro.core import DitherPolicy
from repro.obs import metrics as statslib
from repro.data import ShardedLoader, TokenStreamConfig, token_batch
from repro.models.api import lm_model
from repro.models.transformer import LMConfig
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig


def build_model(tiny: bool):
    if tiny:
        cfg = LMConfig(name="lm-tiny", n_layers=4, d_model=128, n_heads=4,
                       n_kv_heads=2, d_ff=512, vocab=2048,
                       dtype=jnp.float32, remat=False)
    else:
        # ~100M params: 12L x d640 x ff2560 + 32k vocab
        cfg = LMConfig(name="lm-100m", n_layers=12, d_model=640, n_heads=10,
                       n_kv_heads=5, d_ff=2560, vocab=32_000,
                       dtype=jnp.float32, remat=True)
    return lm_model(cfg, family="dense")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--s", type=float, default=2.0)
    ap.add_argument("--variant", default="paper",
                    choices=["off", "paper", "int8", "row"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    model = build_model(args.tiny)
    print(f"model {model.cfg.name}: {model.param_count/1e6:.1f}M params")
    policy = (None if args.variant == "off" else DitherPolicy(
        variant=args.variant, s=args.s, collect_stats=True, stats_tag="lm/"))

    trainer = Trainer(
        model,
        OptConfig(name="adamw", lr=3e-4, schedule="cosine",
                  warmup_steps=args.steps // 20 + 1, total_steps=args.steps,
                  weight_decay=0.01),
        TrainerConfig(total_steps=args.steps,
                      log_every=max(args.steps // 20, 1),
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.steps // 2),
        policy=policy,
    )
    tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=args.seq,
                             batch=args.batch)
    loader = ShardedLoader(lambda s: token_batch(tcfg, s))
    out = trainer.fit(loader)
    loader.close()
    if out["history"]:
        first, last = out["history"][0], out["history"][-1]
        print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
              f"{args.steps} steps")
    if policy is not None:
        print(f"backprop sparsity {statslib.overall_sparsity()*100:.1f}%, "
              f"worst-case bits {statslib.overall_max_bits():.0f}")


if __name__ == "__main__":
    main()
