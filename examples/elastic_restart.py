"""Fault-tolerance drill: train -> lose chips -> elastic re-mesh -> reshard
restore -> continue, end-to-end on CPU.

    PYTHONPATH=src python examples/elastic_restart.py

This exercises the exact sequence a 1000-node run uses after a hardware
event (DESIGN.md §6): the checkpoint's host-complete shards let the restart
land on a SMALLER mesh (TP groups kept whole, data axis rounded down to a
power of two, gradient accumulation scaled up to hold the global batch).
"""
import tempfile


from repro.configs import get_smoke_model
from repro.core import DitherPolicy
from repro.data import TokenStreamConfig, token_batch
from repro.optim import OptConfig
from repro.train import (CheckpointManager, StaticHealthSource, Trainer,
                         TrainerConfig, make_restart_plan)

CKPT = tempfile.mkdtemp(prefix="elastic_")
model = get_smoke_model("minitron-8b")
tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=32, batch=8)


def stream(start=0):
    i = start
    while True:
        yield token_batch(tcfg, i)
        i += 1


def make_trainer(total):
    return Trainer(
        model, OptConfig(lr=1e-3),
        TrainerConfig(total_steps=total, log_every=5, ckpt_every=10,
                      ckpt_dir=CKPT),
        policy=DitherPolicy(variant="paper", s=2.0))


# --- phase 1: healthy run on the "full cluster" -----------------------------
print("== phase 1: train to step 20 on the full mesh (simulated 256 chips)")
t1 = make_trainer(20)
out1 = t1.fit(stream())
loss_before = out1["history"][-1]["loss"]

# --- phase 2: hardware event ------------------------------------------------
health = StaticHealthSource(chips=256)
health.fail(40)  # lose 40 chips (e.g. one faulty rack)
print(f"== phase 2: failure event; {health.alive_chips()} chips alive")
plan = make_restart_plan(
    n_alive_chips=health.alive_chips(), model_parallel=16,
    original_data_parallel=16,
    latest_step=CheckpointManager(CKPT).latest_step())
assert plan is not None, "fewer than one TP group survived"
print(f"   restart plan: mesh {plan.mesh_shape} {plan.mesh_axes}, "
      f"restore step {plan.restore_step}, grad-accum x{plan.grad_accum_scale}")

# --- phase 3: resume on the smaller mesh -------------------------------------
print("== phase 3: restore + continue to step 40 (resharding handled by the")
print("   checkpoint manager; on a real cluster the mesh shrinks to "
      f"{plan.mesh_shape})")
t2 = make_trainer(40)
t2.tcfg.grad_accum = plan.grad_accum_scale  # hold the global batch
out2 = t2.fit(stream())
resumed_from = out2["history"][0]["step"] if out2["history"] else None
loss_after = out2["history"][-1]["loss"]
print(f"resumed around step {resumed_from}; loss {loss_before:.3f} -> "
      f"{loss_after:.3f}")
assert loss_after <= loss_before + 0.1, "resume must not regress the loss"
print("elastic restart drill: OK")
