"""Quickstart: dithered backprop with a per-layer policy program.

    PYTHONPATH=src python examples/quickstart.py

Trains a 2-layer MLP with the paper's NSD-quantized backward pass under a
PolicyProgram: an exact-backprop warmup phase, a linear ramp of the dither
scale s, and a per-layer rule that dithers the first layer harder. Prints
the induced pre-activation-gradient sparsity + worst-case bit-width — the
two quantities of paper Table 1.

The warmup -> paper phase switch recompiles once (the backward variant
shapes the trace); the per-step s ramp is a traced knob and re-uses the
compiled step for the whole run. The memory program stores each layer's
saved forward residual compressed (NSD wire layout by default, affine
int8 for fc2) — also static per layer, also zero recompiles on the ramp.
"""
import jax
import jax.numpy as jnp

from repro.core import (DitherCtx, DitherPolicy, LayerRule, Linear,
                        PhaseSpec, PolicyProgram, dense)
from repro.obs import metrics as statslib
from repro.memory import parse_memory_program

key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)

# toy regression task
X = jax.random.normal(k1, (256, 64))
W_true = jax.random.normal(k2, (64, 1))
Y = X @ W_true + 0.1 * jax.random.normal(k3, (256, 1))

params = {
    "w1": jax.random.normal(k1, (64, 128)) * 0.1,
    "w2": jax.random.normal(k2, (128, 1)) * 0.1,
}

# The program: 20 exact warmup steps, then the paper's NSD backward with
# Delta = s * std(grad) ramping from gentle (1.5) to aggressive (3.0),
# while a rule pins fc1 at s=4.0 (per-layer override, last match wins).
program = PolicyProgram(
    base=DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                      stats_tag="quickstart/"),
    phases=(PhaseSpec(0, "off"), PhaseSpec(20, "paper")),
    s=Linear(20, 150, 1.5, 3.0),
    rules=(LayerRule(pattern="fc1", s=4.0),),
)

# Residual memory: store fc1's saved activations in the NSD wire layout
# (bit-exact vs the nsd operator; ~4-6x smaller) and fc2's in the
# registry's grouped 4-bit codec — any spec from repro.quant.codec_names()
# works here (the memory DSL resolves through the one codec registry).
memory = parse_memory_program("default=nsd;rule fc2:int4@g32")


def loss_fn(p, ctx):
    h = jax.nn.relu(dense(X, p["w1"], ctx=ctx, name="fc1"))
    pred = dense(h, p["w2"], ctx=ctx, name="fc2")
    return jnp.mean((pred - Y) ** 2)


# phase is a static arg (recompiles at the phase boundary, once); the step
# index i and every knob the program derives from it are traced.
def step(p, i, phase):
    ctx = (DitherCtx.for_step(key, i, phase, program=program, memory=memory)
           if phase.enabled else None)
    loss, g = jax.value_and_grad(loss_fn)(p, ctx)
    return jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g), loss


jit_step = jax.jit(step, static_argnames=("phase",))

for i in range(200):
    params, loss = jit_step(params, jnp.int32(i), program.phase_policy_at(i))
    if i % 50 == 0:
        print(f"step {i:4d} loss {float(loss):.4f}")

print(f"final loss {float(loss):.4f}")
summ = statslib.summary()
for layer, s in summ.items():
    print(f"{layer}: mean sparsity {s['mean_sparsity']*100:.1f}% "
          f"worst-case bits {s['max_bits']:.0f}")
print(f"overall sparsity: {statslib.overall_sparsity()*100:.1f}% "
      f"(paper reports 75-99% across models; fc1 runs hotter — its rule "
      f"pins s=4.0)")
for layer, m in statslib.memory_summary().items():
    print(f"{layer}: residual store {m['capacity_bytes']/1e3:.1f} kB "
          f"resident vs {m['dense_bytes']/1e3:.1f} kB dense "
          f"({m['capacity_compression']:.1f}x smaller in HBM; "
          f"{m['occupancy_compression']:.1f}x byte-true occupancy)")
