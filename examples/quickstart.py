"""Quickstart: dithered backprop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a 2-layer MLP with the paper's NSD-quantized backward pass and prints
the induced pre-activation-gradient sparsity + worst-case bit-width — the
two quantities of paper Table 1.
"""
import jax
import jax.numpy as jnp

from repro.core import DitherCtx, DitherPolicy, dense
from repro.core import stats as statslib

key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)

# toy regression task
X = jax.random.normal(k1, (256, 64))
W_true = jax.random.normal(k2, (64, 1))
Y = X @ W_true + 0.1 * jax.random.normal(k3, (256, 1))

params = {
    "w1": jax.random.normal(k1, (64, 128)) * 0.1,
    "w2": jax.random.normal(k2, (128, 1)) * 0.1,
}

# ONE knob: Delta = s * std(grad). collect_stats feeds the telemetry sink.
policy = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                      stats_tag="quickstart/")


def loss_fn(p, ctx):
    h = jax.nn.relu(dense(X, p["w1"], ctx=ctx, name="fc1"))
    pred = dense(h, p["w2"], ctx=ctx, name="fc2")
    return jnp.mean((pred - Y) ** 2)


@jax.jit
def step(p, i):
    ctx = DitherCtx.for_step(key, i, policy)
    loss, g = jax.value_and_grad(loss_fn)(p, ctx)
    return jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g), loss


for i in range(200):
    params, loss = step(params, i)
    if i % 50 == 0:
        print(f"step {i:4d} loss {float(loss):.4f}")

print(f"final loss {float(loss):.4f}")
summ = statslib.summary()
for layer, s in summ.items():
    print(f"{layer}: mean sparsity {s['mean_sparsity']*100:.1f}% "
          f"worst-case bits {s['max_bits']:.0f}")
print(f"overall sparsity: {statslib.overall_sparsity()*100:.1f}% "
      f"(paper reports 75-99% across models)")
