"""One-command Table-1-style reproduction on the synthetic stand-ins.

    PYTHONPATH=src python examples/paper_repro.py
"""
from benchmarks.table1_sparsity import run

rows = run(quick=True, steps=40)
cols = ("model", "baseline_acc", "dithered_acc", "baseline_sparsity",
        "dithered_sparsity", "dithered_bits")
print(" | ".join(f"{c:>18s}" for c in cols))
for r in rows:
    print(" | ".join(f"{r[c]:18.2f}" if isinstance(r[c], float)
                     else f"{r[c]:>18s}" for c in cols))
print("(paper: dithered sparsity 75-99%, accuracy delta ~0.3%, bits <= 8)")
