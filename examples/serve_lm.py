"""Serve a small LM with batched requests through the slot engine.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --new-tokens 12
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_model
from repro.serve import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    model = get_smoke_model(args.arch)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=4, max_len=128))
    rng = np.random.default_rng(0)
    vocab = model.cfg.vocab
    for uid in range(args.requests):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, vocab, size=5),
                           max_new_tokens=args.new_tokens))
    done = eng.run(max_ticks=args.new_tokens * 4)
    for uid in sorted(done):
        print(f"req {uid}: {done[uid]}")
    print(f"served {len(done)}/{args.requests} with "
          f"{min(4, args.requests)}-wide continuous batching")


if __name__ == "__main__":
    main()
